"""Workload-layer benchmarks (DESIGN.md §12): top-k vs full sort, the
streaming-merge tick vs a full re-sort, pytree vs flat payload sort, and
the MoE dispatch before/after (``sorted`` one-hot ranks vs ``argsort``).

The top-k section is a *gate*, not just a figure: at n≥4096 with k≤n/16
the bucket skip rule must beat the full sort on the same input or the
bench raises — the committed ``BENCH_workloads.json`` baseline then holds
the margin, and ``tools/perfguard.py`` re-judges both sides every run.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks import common
from benchmarks.common import emit, measure_interleaved


def _topk_section(paper: bool) -> None:
    from repro.core import SortEngine
    from repro.data.distributions import make_array

    eng = SortEngine()
    sizes = (4096,) if common.SMOKE else (4096, 65536)
    for n in sizes:
        k = n // 16
        x = make_array("random", n, seed=n)
        # interleaved so host drift hits both sides of the ratio equally —
        # the ratio is the gate, per the measure contract
        ms = measure_interleaved({
            "topk": lambda: eng.top_k(x, k),
            "fullsort": lambda: eng.sort(x),
        })
        t_k, t_s = ms["topk"].median_s, ms["fullsort"].median_s
        eng.top_k(x, k)
        rep = eng.last_report or {}
        speedup = t_s / max(t_k, 1e-12)
        emit(
            f"workloads/topk/random/n{n}/k{k}", t_k * 1e6,
            f"fullsort_us={t_s * 1e6:.1f};speedup={speedup:.2f}x;"
            f"skipped={rep.get('skipped_buckets')};kept={rep.get('kept_count')}",
        )
        if n >= 4096 and k <= n // 16 and t_k >= t_s:
            raise RuntimeError(
                f"top-k gate: eng.top_k(n={n}, k={k}) took {t_k * 1e6:.1f}us "
                f">= full sort {t_s * 1e6:.1f}us — the bucket skip rule must "
                "win at n>=4096, k<=n/16"
            )


def _merge_section(paper: bool) -> None:
    from repro.core import SortEngine
    from repro.data.distributions import make_array

    eng = SortEngine()
    n_buf = common.smoke_scaled(65536)
    n_new = common.smoke_scaled(2048)
    buf = np.sort(make_array("random", n_buf, seed=3))
    new = make_array("random", n_new, seed=4)
    whole = np.concatenate([buf, new])
    ms = measure_interleaved({
        "merge_tick": lambda: eng.merge_sorted(buf, new),
        "resort": lambda: eng.sort(whole),
    })
    t_m, t_r = ms["merge_tick"].median_s, ms["resort"].median_s
    emit(
        f"workloads/merge_tick/buf{n_buf}/new{n_new}", t_m * 1e6,
        f"resort_us={t_r * 1e6:.1f};speedup={t_r / max(t_m, 1e-12):.2f}x",
    )


def _pairs_section(paper: bool) -> None:
    from repro.core import SortEngine
    from repro.data.distributions import make_array

    eng = SortEngine()
    n = common.smoke_scaled(4096)
    keys = make_array("random", n, seed=5)
    flat = np.arange(n, dtype=np.int32)
    tree = {
        "idx": np.arange(n, dtype=np.int64),
        "nested": (keys.astype(np.float64), (flat % 251).astype(np.int8)),
    }
    ms = measure_interleaved({
        "flat": lambda: eng.sort_pairs(keys, flat),
        "pytree3": lambda: eng.sort_pairs(keys, tree),
    })
    t_f, t_t = ms["flat"].median_s, ms["pytree3"].median_s
    emit(
        f"workloads/pairs_pytree/n{n}/leaves3", t_t * 1e6,
        f"flat_us={t_f * 1e6:.1f};overhead={t_t / max(t_f, 1e-12):.2f}x",
    )


def _moe_section(paper: bool) -> None:
    """The before/after for the argsort dispatch: same params, same input,
    bit-identical outputs (tests/test_workloads.py) — only rank math
    differs (one-hot cumsum vs one stable argsort)."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import ModelConfig, MoEConfig
    from repro.models import moe as MOE
    from repro.models.common import NO_SHARD

    grid = ((8, 2, 512),) if common.SMOKE else ((8, 2, 4096), (64, 6, 4096))
    for E, k, T in grid:
        cfg = ModelConfig(
            family="moe", d_model=256, dtype=jnp.bfloat16,
            moe=MoEConfig(
                num_experts=E, num_experts_per_tok=k, expert_d_ff=512,
                dispatch="sorted", capacity_factor=1.25,
            ),
        )
        p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, T, 256), jnp.bfloat16)
        cfg_a = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="argsort"))
        f_sorted = jax.jit(lambda x: MOE.apply_moe(p, x, cfg, NO_SHARD)[0])
        f_args = jax.jit(lambda x: MOE.apply_moe(p, x, cfg_a, NO_SHARD)[0])
        ms = measure_interleaved({
            "sorted": lambda: f_sorted(x),
            "argsort": lambda: f_args(x),
        })
        t_s, t_a = ms["sorted"].median_s, ms["argsort"].median_s
        emit(
            f"workloads/moe_dispatch/sorted/E{E}k{k}T{T}", t_s * 1e6,
            f"argsort_us={t_a * 1e6:.1f}",
        )
        emit(
            f"workloads/moe_dispatch/argsort/E{E}k{k}T{T}", t_a * 1e6,
            f"sorted_us={t_s * 1e6:.1f};speedup={t_s / max(t_a, 1e-12):.2f}x",
        )


def run(paper: bool = False) -> None:
    _topk_section(paper)
    _merge_section(paper)
    _pairs_section(paper)
    _moe_section(paper)


if __name__ == "__main__":
    run()
