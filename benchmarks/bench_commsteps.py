"""Theorem 3 — communication-step accounting vs the real schedule.

Reports, for every (dimension × variant): the paper's 12·G·d_h−2 formula,
the actual spanning-tree send count (2·(G·P−1)), the critical-path rounds
(= 2·d_h+3, the topology diameter), the analytic comm-time comparison
paper-schedule vs fused all-to-all (beyond-paper), and — since the
``repro.net`` simulator exists — the *measured* link-level gather time
with its simulated-vs-analytic delta (0 in barrier mode; the dependency
mode's round count exposes the half variant's one-round slack)."""

from __future__ import annotations

from benchmarks.common import dims, emit, smoke_scaled
from repro.core import OHHCTopology
from repro.core.sample_sort import compare_schedules
from repro.core.schedule import AccumulationSchedule
from repro.net.links import LinkModel
from repro.net.sim import simulate_gather


def run(paper: bool = False) -> dict:
    out = {}
    n_total = smoke_scaled(2_621_440)
    for variant in ("full", "half"):
        for d_h in dims():
            topo = OHHCTopology(d_h, variant)
            s = AccumulationSchedule.build(topo)
            cmp = compare_schedules(topo, n_total=n_total)
            chunk = n_total // topo.total_procs
            sim = simulate_gather(
                topo, link_model=LinkModel(), chunk_sizes=chunk, barrier=True
            )
            sim_dep = simulate_gather(
                topo, link_model=LinkModel(), chunk_sizes=chunk
            )
            analytic_one_way = cmp["paper_schedule_s"] / 2.0
            delta = (
                abs(sim.total_time_s - analytic_one_way) / analytic_one_way
                if analytic_one_way > 0
                else 0.0
            )
            out[(variant, d_h)] = (s.paper_step_count(), s.roundtrip_send_count())
            emit(
                f"thm3/commsteps/{variant}/d{d_h}",
                cmp["paper_schedule_s"] * 1e6,
                f"paper_formula={s.paper_step_count()};"
                f"tree_roundtrip={s.roundtrip_send_count()};"
                f"critical_rounds={s.critical_path_rounds()};"
                f"simulated_us={sim.total_time_s*1e6:.1f};"
                f"sim_vs_analytic_delta={delta:.4f};"
                f"sim_dep_us={sim_dep.total_time_s*1e6:.1f};"
                f"fused_exchange_us={cmp['fused_exchange_s']*1e6:.1f};"
                f"fused_speedup={cmp['speedup']:.1f}x",
            )
    return out


if __name__ == "__main__":
    run()
