"""netsim — event-driven link-level gather simulation (DESIGN.md §6).

Per (variant × d_h): simulated gather makespan under the default
electrical/optical ``LinkModel`` (barrier mode, directly comparable to the
analytic Theorem-6 store-and-forward sum), the simulated-vs-analytic
delta, the dependency-mode round count (the half variant's 1-round slack
finding), link utilization, and the one-optical-link-down fault scenario's
slowdown/reroute counters.

``run(paper, json_path=...)`` also writes the full validation report (the
CI artifact) when a path is given; ``python -m benchmarks.bench_netsim
[out.json]`` does the same standalone.
"""

from __future__ import annotations

import sys

from benchmarks import common
from benchmarks.common import DIMS, emit
from repro.net.report import netsim_report, write_json


def run(paper: bool = False, json_path: "str | None" = None) -> dict:
    # d_h=4 (2304-node full OHHC) only on --paper: all-pairs BFS for the
    # diameter check dominates and the 1–3 rows already span the scaling.
    if common.SMOKE:
        sweep, chunk_elems = (1,), 256
    else:
        sweep = tuple(d for d in DIMS if paper or d <= 3)
        chunk_elems = 16384 if paper else 1024
    report = netsim_report(dims=sweep, chunk_elems=chunk_elems)
    for c in report["cases"]:
        f = c["fault"]
        emit(
            f"netsim/gather/{c['variant']}/d{c['d_h']}",
            c["sim_time_us"],
            f"analytic_us={c['analytic_time_us']:.1f};"
            f"delta={c['sim_vs_analytic_delta']:.4f};"
            f"rounds={c['critical_rounds_simulated']};"
            f"dep_rounds={c['dependency_rounds']};"
            f"diameter={c['diameter_measured']}/{c['diameter_expected']};"
            f"util_opt={c['link_utilization']['optical']:.3f};"
            f"fault_slowdown={f['slowdown']:.2f}x;"
            f"fault_reroutes={f['rerouted_messages']};"
            f"fault_contention={f['contention_events']}",
        )
    if json_path:
        write_json(report, json_path)
    return report


if __name__ == "__main__":
    run(json_path=sys.argv[1] if len(sys.argv) > 1 else None)
