"""MoE dispatch (the technique's ML integration): sorted (bucket) dispatch
vs dense one-hot einsum — wall time + dispatch buffer stats on CPU."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import emit, time_call
from repro.configs.base import ModelConfig, MoEConfig
from repro.models import moe as MOE
from repro.models.common import NO_SHARD


def run(paper: bool = False) -> None:
    grid = ((8, 2, 512),) if common.SMOKE else ((8, 2, 4096), (64, 6, 4096))
    for E, k, T in grid:
        cfg = ModelConfig(
            family="moe", d_model=256, dtype=jnp.bfloat16,
            moe=MoEConfig(num_experts=E, num_experts_per_tok=k, expert_d_ff=512,
                          dispatch="sorted", capacity_factor=1.25),
        )
        p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, T, 256), jnp.bfloat16)
        f_sorted = jax.jit(lambda x: MOE.apply_moe(p, x, cfg, NO_SHARD)[0])
        cfg_d = cfg.replace(moe=MoEConfig(num_experts=E, num_experts_per_tok=k,
                                          expert_d_ff=512, dispatch="dense"))
        f_dense = jax.jit(lambda x: MOE.apply_moe(p, x, cfg_d, NO_SHARD)[0])
        t_s = time_call(lambda: f_sorted(x).block_until_ready())
        t_d = time_call(lambda: f_dense(x).block_until_ready())
        emit(f"moe/sorted_dispatch/E{E}k{k}", t_s * 1e6, f"dense_us={t_d*1e6:.0f};speedup={t_d/t_s:.2f}x")


if __name__ == "__main__":
    run()
