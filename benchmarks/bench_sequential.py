"""Fig 6.1 — sequential quicksort over array types × sizes.

Baseline T_S for every speedup/efficiency figure.  np.sort(kind=quicksort)
is the C-grade sequential quicksort (introsort); the paper's observation —
sorted/reverse-sorted inputs run faster than random — reproduces."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, n_for_mb, sizes_mb, time_call
from repro.data.distributions import DISTRIBUTIONS, make_array


def run(paper: bool = False) -> dict:
    ts = {}
    for mb in sizes_mb(paper):
        n = n_for_mb(mb)
        for dist in DISTRIBUTIONS:
            x = make_array(dist, n, seed=mb)
            t = time_call(lambda: np.sort(x, kind="quicksort"), repeats=3)
            ts[(dist, mb)] = t
            emit(f"fig6.1/sequential/{dist}/{mb}MB", t * 1e6, f"n={n}")
    return ts


if __name__ == "__main__":
    run()
