"""Fleet serving benchmark — scaling + chaos (beyond paper, DESIGN.md §10).

Two measurements, both driven through ``repro.serve.fleet.loadgen`` so
the comparison workload is literally shared with the gated perf cases
(``repro.perf.suites`` fleet suite) and the chaos tests:

1. **Scaling** — the fleet acceptance gate: the same closed-loop request
   mix (three shape buckets + ~2% oversize) is driven through (a) one
   :class:`repro.serve.sortd.Sortd` with its shipped default config and
   (b) a :class:`repro.serve.fleet.SortdFleet` at ``--workers``.  The
   derived ``ratio_vs_single`` is fleet-rps / single-rps; the contract is
   ≥ 2.0 at 4 workers in the latency-bound regime (low ``--clients``).
   On this 1-core container the fleet's win is scheduling, not parallel
   compute: fleet workers run the idle-flush policy (DESIGN.md §10),
   eliminating the single service's coalescing-deadline idle; client
   counts high enough to keep the queue non-empty amortize that deadline
   and shrink the gap — the bench sweeps ``--clients`` in ``--paper``
   mode so the crossover is visible rather than hidden.

2. **Chaos** — ``--chaos`` kills the busiest worker mid-load
   (:class:`repro.serve.fleet.ChaosConfig`, deterministic admission-count
   trigger) under a C=8 closed loop, then checks EVERY response
   byte-identical against ``np.sort`` — zero wrong or lost answers is the
   contract, failover latency is the cost: the report carries healthy
   vs chaos p99 and the degradation ratio, plus the fleet's failover /
   re-admission counters and the matching ``net.faults`` scenario name.

CSV rows carry per-request microseconds; the JSON report
(``--fleet-report``, the CI artifact) mirrors ``net.report`` /
``sortd_report.json`` — see ``benchmarks/README.md``.
"""

from __future__ import annotations

import json
import sys

import numpy as np

from benchmarks import common
from benchmarks.common import DEFAULT_DTYPE, emit
from repro.core import OHHCTopology, SortEngine
from repro.serve.fleet import ChaosConfig, FleetConfig, SortdFleet
from repro.serve.fleet.loadgen import drive_closed_loop, request_mix
from repro.serve.sortd import Sortd, SortdConfig

ROUNDS = 3  # best-of rounds per configuration (thread-timing noise)
WARM_REQS = 60


def _n_requests(paper: bool) -> int:
    return 40 if common.SMOKE else (800 if paper else 400)


def _drive_best(submit, reqs, clients: int, rounds: int = ROUNDS):
    """Best-of-``rounds`` closed-loop wall time (and last outs)."""
    best, outs = float("inf"), None
    for _ in range(rounds):
        wall, outs = drive_closed_loop(submit, reqs, clients=clients)
        best = min(best, wall)
    return best, outs


def _bench_scaling(paper: bool, dtype, workers: int, clients: int,
                   report: dict) -> None:
    n_req = _n_requests(paper)
    warm = request_mix(WARM_REQS, dtype=dtype, seed=3)
    reqs = request_mix(n_req, dtype=dtype, seed=11)
    rounds = 1 if common.SMOKE else ROUNDS
    client_counts = (clients, 8) if paper else (clients,)
    rows = {}
    for C in client_counts:
        with Sortd(SortEngine(OHHCTopology(1, "full")),
                   SortdConfig(max_queue=4096)) as single:
            drive_closed_loop(single.submit, warm, clients=C)
            t_single, _ = _drive_best(single.submit, reqs, C, rounds)
        with SortdFleet(FleetConfig(workers=workers)) as fleet:
            drive_closed_loop(fleet.submit, warm, clients=C)
            t_fleet, outs = _drive_best(fleet.submit, reqs, C, rounds)
            fm = fleet.metrics()["fleet"]
        # spot-check correctness (full check lives in the chaos section)
        for i in range(0, n_req, 37):
            np.testing.assert_array_equal(outs[i], np.sort(reqs[i]))
        rps_single, rps_fleet = n_req / t_single, n_req / t_fleet
        ratio = rps_fleet / rps_single
        emit(
            f"fleet/scaling/single/c{C}",
            t_single / n_req * 1e6,
            f"rps={rps_single:.0f}",
        )
        emit(
            f"fleet/scaling/w{workers}/c{C}",
            t_fleet / n_req * 1e6,
            f"rps={rps_fleet:.0f};ratio_vs_single={ratio:.2f};"
            f"steals={fm['steals']};p99_ms={fm['latency_ms']['p99']:.2f}",
        )
        rows[f"c{C}"] = {
            "clients": C,
            "requests": n_req,
            "single_rps": rps_single,
            "fleet_rps": rps_fleet,
            "ratio_vs_single": ratio,
            "fleet_p99_ms": fm["latency_ms"]["p99"],
            "steals": fm["steals"],
        }
    report["scaling"] = {"workers": workers, "rounds": rounds, **rows}


def _bench_chaos(paper: bool, dtype, workers: int, report: dict) -> None:
    n_req = _n_requests(paper)
    clients = 8
    warm = request_mix(WARM_REQS, dtype=dtype, seed=3)
    reqs = request_mix(n_req, dtype=dtype, seed=11)

    def run_fleet(chaos):
        with SortdFleet(FleetConfig(workers=workers), chaos=chaos) as fleet:
            drive_closed_loop(fleet.submit, warm, clients=clients)
            wall, outs = drive_closed_loop(fleet.submit, reqs, clients=clients)
            return wall, outs, fleet.report()

    wall_h, _, rep_h = run_fleet(None)
    chaos = ChaosConfig(
        name="kill-busiest-midload", kill_worker_after=WARM_REQS + n_req // 3
    )
    wall_c, outs, rep_c = run_fleet(chaos)
    # the contract: every answer present and byte-identical, no exceptions
    wrong = sum(
        0 if np.array_equal(o, np.sort(r)) else 1 for o, r in zip(outs, reqs)
    )
    if wrong:
        raise AssertionError(f"chaos run returned {wrong}/{n_req} wrong results")
    p99_h = rep_h["fleet"]["latency_ms"]["p99"]
    p99_c = rep_c["fleet"]["latency_ms"]["p99"]
    degradation = p99_c / p99_h if p99_h > 0 else float("inf")
    emit(
        "fleet/chaos/kill_busiest",
        wall_c / n_req * 1e6,
        f"wrong=0;killed=w{rep_c['chaos']['killed_worker']};"
        f"failovers={rep_c['fleet']['failovers']};"
        f"readmitted={rep_c['fleet']['readmitted']};"
        f"p99_ms={p99_c:.2f};p99_degradation={degradation:.2f}",
    )
    report["chaos"] = {
        "requests": n_req,
        "clients": clients,
        "wrong_results": 0,
        "healthy_wall_s": wall_h,
        "chaos_wall_s": wall_c,
        "healthy_p99_ms": p99_h,
        "chaos_p99_ms": p99_c,
        "p99_degradation": degradation,
        "fleet_report": rep_c,
    }


def run(
    paper: bool = False,
    dtype: str = DEFAULT_DTYPE,
    *,
    workers: int = 4,
    clients: int = 2,
    chaos: bool = True,
    report: "str | None" = "fleet_report.json",
) -> dict:
    doc: dict = {
        "suite": "fleet",
        "dtype": dtype,
        "config": {"workers": workers, "clients": clients, "chaos": chaos},
    }
    _bench_scaling(paper, dtype, workers, clients, doc)
    if chaos:
        _bench_chaos(paper, dtype, workers, doc)
    if report:
        with open(report, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"# fleet report written: {report}", file=sys.stderr)
    return doc


if __name__ == "__main__":
    run(report=sys.argv[1] if len(sys.argv) > 1 else "fleet_report.json")
