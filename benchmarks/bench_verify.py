"""Conformance grid as a benchmark suite (DESIGN.md §7).

Runs the verify grid — the tier-1 slice by default, the full smoke grid
under ``--paper`` — and emits per-(path, method) timing plus the pass
count, so a perf regression in any executor shows up in the same CSV
stream as the paper-figure benchmarks.  ``--dtype`` narrows the sweep to
one key type (the paper's "different integer array types" axis).
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro.verify import differential, grid


def run(paper: bool = False, dtype: str | None = None) -> dict:
    """``dtype=None`` sweeps every key type; an explicit dtype (run.py's
    ``--dtype``) narrows the grid to that one so rows stay comparable."""
    scenarios = grid.smoke_grid(devices=1) if paper else grid.tier1_grid()
    if dtype is None and common.SMOKE:
        dtype = "int32"  # one key type is enough to validate wiring
    if dtype is not None:
        scenarios = [sc for sc in scenarios if sc.dtype == dtype]
    # Warm-up pass on shared engines, then time: the first execution of
    # each (shape bucket, capacity, method, dtype) pays jit compilation,
    # which would otherwise dominate the mean and hide real sort slowdowns.
    engines = differential.EngineCache(devices=1)
    differential.run_grid(scenarios, keep_outputs=False, engines=engines)
    results = differential.run_grid(scenarios, keep_outputs=False, engines=engines)
    groups: dict[tuple[str, str], list] = {}
    for r in results:
        groups.setdefault((r.path, r.method), []).append(r)
    out = {}
    for (path, method), rs in sorted(groups.items()):
        fails = sum(1 for r in rs if r.status != "pass")
        mean_us = float(np.mean([r.elapsed_s for r in rs])) * 1e6
        out[(path, method)] = {"scenarios": len(rs), "fails": fails}
        emit(
            f"verify/{path}/{method}",
            mean_us,
            f"scenarios={len(rs)};fails={fails}",
        )
    return out


if __name__ == "__main__":
    run()
