"""Figs 6.2/6.3 — parallel OHHC quicksort execution time over dimensions
(1–4), distributions and sizes.

T_P uses the paper's own metric — "the time of the last thread finish":
max per-processor bucket sort time (measured) + the communication model
over the real accumulation schedule (store-and-forward, per-round largest
message, electrical vs optical bandwidths — the link asymmetry the paper
explicitly could NOT simulate)."""

from __future__ import annotations

from benchmarks.common import dims, emit, n_for_mb, sizes_mb
from repro.core import OHHCTopology, ohhc_sort_host
from repro.data.distributions import DISTRIBUTIONS, make_array


def run(paper: bool = False, variant: str = "full", method: str = "paper") -> dict:
    out = {}
    for d_h in dims():
        topo = OHHCTopology(d_h, variant)
        for dist in DISTRIBUTIONS:
            for mb in sizes_mb(paper):
                n = n_for_mb(mb)
                x = make_array(dist, n, seed=mb)
                r = ohhc_sort_host(x, topo, method=method)
                t = r.t_parallel_model_s
                out[(d_h, dist, mb)] = r
                emit(
                    f"fig6.2/parallel/{variant}/d{d_h}/{dist}/{mb}MB",
                    t * 1e6,
                    f"procs={topo.total_procs};maxsort_us={r.local_sort_times_s.max()*1e6:.0f};"
                    f"comm_us={r.comm_model_time_s*1e6:.0f};"
                    f"imb={r.bucket_sizes.max()/max(r.bucket_sizes.mean(),1e-9):.2f}",
                )
    return out


if __name__ == "__main__":
    run()
