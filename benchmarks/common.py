"""Shared benchmark plumbing: timing discipline, CSV output, size grids.

Paper sizes are 10–60 MB of int32 (2.62M–15.7M elements).  The default
grid is scaled down (see ``--paper`` in run.py) because this container has
ONE CPU core — full-size runs are supported but slow.  ``--smoke``
(``set_smoke``) shrinks every axis to a wiring-validation slice: sizes cap
at :data:`SMOKE_MAX_ELEMS`, the dimension sweep narrows, and per-module
scenario counts drop — numbers from a smoke run validate that the suites
*run and emit schema-valid rows* (``tests/test_bench_smoke.py``), never
performance.  Every benchmark prints ``name,us_per_call,derived`` CSV rows
per the harness contract (validated by ``repro.perf.schema.parse_csv_row``).

Timing goes through the ``repro.perf.measure`` contract (DESIGN.md §9):
warmup outside the timed region, async results drained before the clock
stops, median-of-k with IQR.  ``time_call`` keeps the historical
median-seconds signature on top of it; new code should use ``measure`` /
``measure_interleaved`` directly so dispersion rides along.  All benchmark
RNG must come from :func:`bench_rng` (or an explicit ``seed=`` in
``make_array``) — a benchmark that draws from an unseeded generator can
never be compared across runs.
"""

from __future__ import annotations

import numpy as np

from repro.data.distributions import DISTRIBUTIONS, elements_for_mb
from repro.perf.measure import (  # noqa: F401  (re-exported bench surface)
    Measurement,
    measure,
    measure_interleaved,
)

SMALL_SIZES_MB = (1, 2, 4)
PAPER_SIZES_MB = (10, 20, 30, 40, 50, 60)
DIMS = (1, 2, 3, 4)

# --smoke slice: one nominal size row, capped element counts, two dims.
SMOKE_SIZES_MB = (1,)
SMOKE_MAX_ELEMS = 16_384
SMOKE_DIMS = (1, 2)

# The paper's "different integer array types" axis (+ float32, §2's native
# key type).  ``--dtype`` on run.py selects one; int32 is the paper default.
DTYPES = ("int8", "int16", "int32", "int64", "uint32", "float32")
DEFAULT_DTYPE = "int32"

# Module state, not an import-time constant: run.py's --smoke flag flips it
# after imports, so every helper below must consult it at call time.
SMOKE = False


def set_smoke(on: bool = True) -> None:
    global SMOKE
    SMOKE = bool(on)


def resolve_dtype(name: str) -> np.dtype:
    if name not in DTYPES:
        raise ValueError(f"unknown dtype {name!r}; choose from {DTYPES}")
    return np.dtype(name)


def sizes_mb(paper: bool):
    if SMOKE:
        return SMOKE_SIZES_MB
    return PAPER_SIZES_MB if paper else SMALL_SIZES_MB


def dims():
    """The OHHC dimension sweep (consult at call time — see SMOKE)."""
    return SMOKE_DIMS if SMOKE else DIMS


def n_for_mb(mb: int) -> int:
    n = elements_for_mb(mb)
    return min(n, SMOKE_MAX_ELEMS) if SMOKE else n


def smoke_scaled(n: int) -> int:
    """Cap an explicit element count in smoke mode (for the modules whose
    sizes don't come from the MB grid, e.g. the counter walks)."""
    return min(n, SMOKE_MAX_ELEMS) if SMOKE else n


def bench_rng(seed: int) -> np.random.Generator:
    """THE benchmark RNG constructor: explicit seed, no ambient state."""
    return np.random.default_rng(seed)


def time_call(fn, *args, repeats: int = 3, **kw) -> float:
    """Median wall time in seconds (median-of-``repeats`` after 1 warmup,
    async results drained — the ``repro.perf.measure`` contract)."""
    return measure(lambda: fn(*args, **kw), warmup=1, repeats=repeats).median_s


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
