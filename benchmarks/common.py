"""Shared benchmark plumbing: timing, CSV output, size/distribution grids.

Paper sizes are 10–60 MB of int32 (2.62M–15.7M elements).  The default
grid is scaled down (see ``--paper`` in run.py) because this container has
ONE CPU core — full-size runs are supported but slow.  Every benchmark
prints ``name,us_per_call,derived`` CSV rows per the harness contract.
"""

from __future__ import annotations

import time

import numpy as np

from repro.data.distributions import DISTRIBUTIONS, elements_for_mb

SMALL_SIZES_MB = (1, 2, 4)
PAPER_SIZES_MB = (10, 20, 30, 40, 50, 60)
DIMS = (1, 2, 3, 4)

# The paper's "different integer array types" axis (+ float32, §2's native
# key type).  ``--dtype`` on run.py selects one; int32 is the paper default.
DTYPES = ("int8", "int16", "int32", "int64", "uint32", "float32")
DEFAULT_DTYPE = "int32"


def resolve_dtype(name: str) -> np.dtype:
    if name not in DTYPES:
        raise ValueError(f"unknown dtype {name!r}; choose from {DTYPES}")
    return np.dtype(name)


def sizes_mb(paper: bool):
    return PAPER_SIZES_MB if paper else SMALL_SIZES_MB


def time_call(fn, *args, repeats: int = 3, **kw) -> float:
    """Median wall time in seconds."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def n_for_mb(mb: int) -> int:
    return elements_for_mb(mb)
