"""Degraded-topology gather benchmark — measured vs predicted slowdown
per fault scenario (beyond paper, DESIGN.md §11).

For each k-link fault scenario the engine can serve through
(``repro.net.faults``), two numbers from the same event-driven simulator:

* **predicted** — the barrier (BSP) accounting of the degraded schedule,
  the number the engine quotes in ``SortPlan.reason`` when it re-prices a
  plan under a fault scenario;
* **measured**  — the dependency-mode (contention-aware, overlapping)
  run of the *same* degraded schedule, i.e. what the modeled network
  actually does.

The derived column carries both slowdown ratios plus their agreement
(``measured / predicted``).  The in-bench gate (the ``bench_kernels``
autotune-slack precedent) pins the model contract: a degraded gather must
actually be slower (measured ≥ 1), the BSP prediction must be
conservative (measured ≤ predicted, within slack — dependency mode
overlaps rounds the barrier model serializes), and the agreement must not
collapse (a prediction several times the measured cost would make the
engine's quoted slowdowns meaningless).  Impossible scenarios (an
optically islanded group, a dead hub node) are emitted as rows too — the
typed ``GatherImpossible`` verdict with the offending node count is the
datum, and the engine's host fallback is the recorded behavior.

Wall-clock cost of the rebuild + simulation machinery is gated separately
by the ``faults`` perf suite (``repro.perf.suites`` → ``BENCH_faults.json``
via tools/perfguard.py); rows here are *simulated* gather seconds, which
are deterministic and machine-independent.
"""

from __future__ import annotations

from benchmarks import common
from benchmarks.common import emit

# Agreement band for measured/predicted (deterministic simulator output;
# the spread across topologies and chunk sizes is ~0.63-0.80).
AGREE_LO = 0.35
AGREE_SLACK = 1.05  # measured may exceed predicted by at most 5%


def _scenarios(topo):
    from repro.net.faults import FaultScenario

    return [
        FaultScenario.optical_link_down(1),
        FaultScenario.random_links(topo, 2, seed=3),
        FaultScenario.random_links(topo, 4, seed=3),
        FaultScenario.group_uplinks_down(topo, 1),
        FaultScenario.worker_down(1),
    ]


def run(paper: bool = False) -> dict:
    from repro.core.topology import OHHCTopology
    from repro.net.faults import GatherImpossible, predicted_slowdown

    n = 1 << 14 if common.SMOKE else (1 << 20 if paper else 1 << 16)
    dims = (1,) if common.SMOKE else (1, 2)
    doc: dict = {"suite": "faults", "n": n, "rows": {}}
    for d_h in dims:
        topo = OHHCTopology(d_h, "full")
        chunk = max(1, n // topo.total_procs)
        for sc in _scenarios(topo):
            key = f"faults/{sc.name}/d{d_h}"
            try:
                healthy_s, pred_s, pred = predicted_slowdown(
                    topo, sc, chunk_sizes=chunk, barrier=True
                )
                _, meas_s, meas = predicted_slowdown(
                    topo, sc, chunk_sizes=chunk, barrier=False
                )
            except GatherImpossible as e:
                # The typed refusal IS the result: the engine serves this
                # scenario on the host fallback (DESIGN.md §11).
                emit(
                    key,
                    0.0,  # no degraded gather exists to time
                    f"impossible;nodes={len(e.nodes)};fallback=host",
                )
                doc["rows"][key] = {
                    "impossible": True,
                    "nodes": sorted(e.nodes),
                }
                continue
            agree = meas / pred
            emit(
                key,
                meas_s * 1e6,
                f"pred_x={pred:.3f};meas_x={meas:.3f};agree={agree:.3f}",
            )
            doc["rows"][key] = {
                "impossible": False,
                "healthy_s": healthy_s,
                "predicted_s": pred_s,
                "measured_s": meas_s,
                "predicted_slowdown": pred,
                "measured_slowdown": meas,
                "agreement": agree,
            }
            if meas < 1.0 - 1e-9:
                raise RuntimeError(
                    f"{key}: degraded gather faster than healthy "
                    f"(measured x{meas:.3f}) — the fault injection is a no-op"
                )
            if agree > AGREE_SLACK:
                raise RuntimeError(
                    f"{key}: measured slowdown x{meas:.3f} exceeds the BSP "
                    f"prediction x{pred:.3f} by more than {AGREE_SLACK}x — "
                    "the quoted prediction is no longer conservative"
                )
            if agree < AGREE_LO:
                raise RuntimeError(
                    f"{key}: measured/predicted agreement {agree:.3f} below "
                    f"{AGREE_LO} — the predicted slowdown the engine quotes "
                    "has decoupled from the simulated network"
                )
    return doc


if __name__ == "__main__":
    run()
