"""Figs 6.20–6.24 — recursion calls / iterations / swaps counters.

Instrumented middle-pivot quicksort (Hoare swap semantics) summed over the
per-processor buckets, dimensions 1–4, Random vs Sorted — reproducing:
* recursions ~steady across dims, iterations drop significantly (6.20/21)
* sorted swaps ≪ random swaps (6.22)
* higher dimension → fewer comparisons (6.23), swaps ~flat (6.24)

Size note: counters walk segments in Python; default 1M elements (the
paper's 30MB=7.9M with --paper)."""

from __future__ import annotations

from benchmarks.common import dims, emit, smoke_scaled
from repro.core import OHHCTopology, bitonic_counters, parallel_quicksort_counters
from repro.data.distributions import make_array


def run(paper: bool = False) -> dict:
    n = smoke_scaled(7_864_320 if paper else 1_000_000)
    out = {}
    for dist in ("random", "sorted"):
        x = make_array(dist, n, seed=30).astype("int64")
        for d_h in dims():
            topo = OHHCTopology(d_h, "full")
            c = parallel_quicksort_counters(x, topo)
            out[(dist, d_h)] = c
            emit(
                f"fig6.20-24/counters/{dist}/d{d_h}",
                0.0,
                f"recursions={c.recursion_calls};iterations={c.iterations};"
                f"swaps={c.swaps};procs={topo.total_procs}",
            )
    # TPU-native local sort (bitonic network) closed-form comparisons for the
    # same bucket sizes — the hardware-adaptation counterpart of Fig 6.23.
    for d_h in dims():
        topo = OHHCTopology(d_h, "full")
        bc = bitonic_counters(n // topo.total_procs)
        emit(
            f"fig6.23/bitonic/d{d_h}",
            0.0,
            f"comparisons_per_bucket={bc['comparisons']};stages={bc['stages']}",
        )
    return out


if __name__ == "__main__":
    run()
