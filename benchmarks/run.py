"""Benchmark harness — one module per paper table/figure family.

``PYTHONPATH=src python -m benchmarks.run [--paper] [--only NAME]``

Prints ``name,us_per_call,derived`` CSV.  ``--paper`` uses the paper's
exact 10–60 MB sizes (slow on this 1-core container); the default grid is
1–4 MB with identical structure.
"""

from __future__ import annotations

import argparse

from benchmarks import (
    bench_commsteps,
    bench_counters,
    bench_efficiency,
    bench_engine,
    bench_kernels,
    bench_moe_dispatch,
    bench_netsim,
    bench_parallel,
    bench_sequential,
    bench_speedup,
)

SUITES = {
    "sequential": lambda paper: bench_sequential.run(paper),  # Fig 6.1
    "parallel": lambda paper: bench_parallel.run(paper),  # Figs 6.2/6.3
    "speedup_full": lambda paper: bench_speedup.run(paper, "full"),  # 6.4–6.7
    "speedup_half": lambda paper: bench_speedup.run(paper, "half"),  # 6.8–6.11
    "efficiency_full": lambda paper: bench_efficiency.run(paper, "full"),  # 6.12–15
    "efficiency_half": lambda paper: bench_efficiency.run(paper, "half"),  # 6.16–19
    "counters": lambda paper: bench_counters.run(paper),  # 6.20–6.24
    "commsteps": lambda paper: bench_commsteps.run(paper),  # Theorem 3
    "kernels": lambda paper: bench_kernels.run(paper),
    "moe_dispatch": lambda paper: bench_moe_dispatch.run(paper),
    "engine": lambda paper: bench_engine.run(paper),  # autotuned dispatch
    "netsim": lambda paper: bench_netsim.run(paper),  # link-level simulation
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true", help="paper-exact 10-60MB sizes")
    ap.add_argument("--only", default=None, choices=list(SUITES))
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        fn(args.paper)


if __name__ == "__main__":
    main()
