"""Benchmark harness — one module per paper table/figure family.

``PYTHONPATH=src python -m benchmarks.run [--paper|--smoke] [--suite NAME]
[--dtype D]``

Prints ``name,us_per_call,derived`` CSV with a ``# suite=<name>`` marker
line before each suite's rows.  ``--paper`` uses the paper's exact
10–60 MB sizes (slow on this 1-core container); the default grid is
1–4 MB with identical structure; ``--smoke`` shrinks every axis to the
wiring-validation slice ``tests/test_bench_smoke.py`` gates (numbers not
comparable to real runs).  ``--dtype`` selects the key type for the
suites that sweep the paper's "different integer array types" axis
(``engine``, ``verify``, ``sortd``); the rest pin the paper's int32.  The
``sortd`` suite additionally honours ``--arrival/--rate/--clients`` (load
generator shape) and ``--report`` (JSON report path); the ``fleet`` suite
honours ``--workers/--fleet-clients/--chaos/--no-chaos/--fleet-report`` —
see ``benchmarks/README.md``.
"""

from __future__ import annotations

import argparse

from benchmarks import (
    bench_commsteps,
    bench_counters,
    bench_efficiency,
    bench_engine,
    bench_faults,
    bench_fleet,
    bench_kernels,
    bench_moe_dispatch,
    bench_netsim,
    bench_parallel,
    bench_sequential,
    bench_sortd,
    bench_speedup,
    bench_verify,
    bench_workloads,
)
from benchmarks import common
from benchmarks.common import DEFAULT_DTYPE, DTYPES

SUITES = {
    "sequential": lambda a: bench_sequential.run(a.paper),  # Fig 6.1
    "parallel": lambda a: bench_parallel.run(a.paper),  # Figs 6.2/6.3
    "speedup_full": lambda a: bench_speedup.run(a.paper, "full"),  # 6.4–6.7
    "speedup_half": lambda a: bench_speedup.run(a.paper, "half"),  # 6.8–6.11
    "efficiency_full": lambda a: bench_efficiency.run(a.paper, "full"),  # 6.12–15
    "efficiency_half": lambda a: bench_efficiency.run(a.paper, "half"),  # 6.16–19
    "counters": lambda a: bench_counters.run(a.paper),  # 6.20–6.24
    "commsteps": lambda a: bench_commsteps.run(a.paper),  # Theorem 3
    "kernels": lambda a: bench_kernels.run(a.paper),
    "moe_dispatch": lambda a: bench_moe_dispatch.run(a.paper),
    "engine": lambda a: bench_engine.run(
        a.paper, dtype=a.dtype or DEFAULT_DTYPE
    ),  # autotuned dispatch
    "netsim": lambda a: bench_netsim.run(a.paper),  # link-level simulation
    "verify": lambda a: bench_verify.run(a.paper, dtype=a.dtype),  # conformance grid
    "sortd": lambda a: bench_sortd.run(  # serving layer (DESIGN.md §8)
        a.paper,
        dtype=a.dtype or DEFAULT_DTYPE,
        arrival=a.arrival,
        rate=a.rate,
        clients=a.clients,
        report=a.report,
    ),
    "fleet": lambda a: bench_fleet.run(  # multi-worker serving (DESIGN.md §10)
        a.paper,
        dtype=a.dtype or DEFAULT_DTYPE,
        workers=a.workers,
        clients=a.fleet_clients,
        chaos=a.chaos,
        report=a.fleet_report,
    ),
    "faults": lambda a: bench_faults.run(a.paper),  # degraded serving (§11)
    "workloads": lambda a: bench_workloads.run(a.paper),  # op layer (§12)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true", help="paper-exact 10-60MB sizes")
    ap.add_argument(
        "--smoke", action="store_true",
        help="wiring-validation slice: capped sizes, narrowed sweeps "
        "(tests/test_bench_smoke.py runs every suite this way; numbers are "
        "NOT comparable to real runs)",
    )
    ap.add_argument(
        "--only", "--suite", dest="only", default=None, choices=list(SUITES),
        help="run one suite (--suite is an alias)",
    )
    ap.add_argument(
        "--dtype", default=None, choices=list(DTYPES),
        help="key dtype for the dtype-swept suites (engine/sortd default to "
        f"{DEFAULT_DTYPE}; verify sweeps all dtypes unless narrowed)",
    )
    sortd = ap.add_argument_group("sortd suite")
    sortd.add_argument(
        "--arrival", default="both", choices=("open", "closed", "both", "none"),
        help="load-generator mode: open-loop (fixed arrival rate), "
        "closed-loop (N waiting clients), both, or none (throughput gate only)",
    )
    sortd.add_argument(
        "--rate", type=float, default=300.0,
        help="open-loop arrival rate in requests/s",
    )
    sortd.add_argument(
        "--clients", type=int, default=4,
        help="closed-loop concurrent client count",
    )
    sortd.add_argument(
        "--report", default="sortd_report.json",
        help="sortd JSON report path ('' disables)",
    )
    fleet = ap.add_argument_group("fleet suite")
    fleet.add_argument(
        "--workers", type=int, default=4,
        help="fleet worker count for the scaling comparison",
    )
    fleet.add_argument(
        "--fleet-clients", type=int, default=2,
        help="closed-loop clients for the fleet scaling gate (the "
        "latency-bound regime; --paper also sweeps c=8)",
    )
    fleet.add_argument(
        "--chaos", dest="chaos", action="store_true", default=True,
        help="run the chaos section (kill the busiest worker mid-load)",
    )
    fleet.add_argument("--no-chaos", dest="chaos", action="store_false")
    fleet.add_argument(
        "--fleet-report", default="fleet_report.json",
        help="fleet JSON report path ('' disables)",
    )
    args = ap.parse_args()
    if args.smoke and args.paper:
        ap.error("--smoke and --paper are mutually exclusive")
    if args.smoke:
        common.set_smoke(True)
    print("name,us_per_call,derived")
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        # section marker (comment row): lets consumers attribute rows to
        # suites without pattern-matching the heterogeneous row names
        print(f"# suite={name}")
        fn(args)


if __name__ == "__main__":
    main()
