"""Benchmark harness — one module per paper table/figure family.

``PYTHONPATH=src python -m benchmarks.run [--paper] [--only NAME] [--dtype D]``

Prints ``name,us_per_call,derived`` CSV.  ``--paper`` uses the paper's
exact 10–60 MB sizes (slow on this 1-core container); the default grid is
1–4 MB with identical structure.  ``--dtype`` selects the key type for the
suites that sweep the paper's "different integer array types" axis
(``engine``, ``verify``); the rest pin the paper's int32.
"""

from __future__ import annotations

import argparse

from benchmarks import (
    bench_commsteps,
    bench_counters,
    bench_efficiency,
    bench_engine,
    bench_kernels,
    bench_moe_dispatch,
    bench_netsim,
    bench_parallel,
    bench_sequential,
    bench_speedup,
    bench_verify,
)
from benchmarks.common import DEFAULT_DTYPE, DTYPES

SUITES = {
    "sequential": lambda paper, dtype: bench_sequential.run(paper),  # Fig 6.1
    "parallel": lambda paper, dtype: bench_parallel.run(paper),  # Figs 6.2/6.3
    "speedup_full": lambda paper, dtype: bench_speedup.run(paper, "full"),  # 6.4–6.7
    "speedup_half": lambda paper, dtype: bench_speedup.run(paper, "half"),  # 6.8–6.11
    "efficiency_full": lambda paper, dtype: bench_efficiency.run(paper, "full"),  # 6.12–15
    "efficiency_half": lambda paper, dtype: bench_efficiency.run(paper, "half"),  # 6.16–19
    "counters": lambda paper, dtype: bench_counters.run(paper),  # 6.20–6.24
    "commsteps": lambda paper, dtype: bench_commsteps.run(paper),  # Theorem 3
    "kernels": lambda paper, dtype: bench_kernels.run(paper),
    "moe_dispatch": lambda paper, dtype: bench_moe_dispatch.run(paper),
    "engine": lambda paper, dtype: bench_engine.run(paper, dtype=dtype or DEFAULT_DTYPE),  # autotuned dispatch
    "netsim": lambda paper, dtype: bench_netsim.run(paper),  # link-level simulation
    "verify": lambda paper, dtype: bench_verify.run(paper, dtype=dtype),  # conformance grid (None = all dtypes)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true", help="paper-exact 10-60MB sizes")
    ap.add_argument("--only", default=None, choices=list(SUITES))
    ap.add_argument(
        "--dtype", default=None, choices=list(DTYPES),
        help="key dtype for the dtype-swept suites (engine defaults to "
        f"{DEFAULT_DTYPE}; verify sweeps all dtypes unless narrowed)",
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        fn(args.paper, args.dtype)


if __name__ == "__main__":
    main()
