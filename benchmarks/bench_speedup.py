"""Figs 6.4–6.11 — relative speedup S = T_S / T_P for G=P (full) and
G=P/2 (half) across dimensions, distributions, sizes.

Also runs the beyond-paper sampled-splitter variant side by side: the
paper's 'local distribution stalls at ~10%' pathology disappears."""

from __future__ import annotations

import numpy as np

from benchmarks.common import dims, emit, n_for_mb, sizes_mb, time_call
from repro.core import OHHCTopology, ohhc_sort_host
from repro.data.distributions import DISTRIBUTIONS, make_array


def run(paper: bool = False, variant: str = "full") -> dict:
    fig = "fig6.4-7" if variant == "full" else "fig6.8-11"
    out = {}
    for dist in DISTRIBUTIONS:
        for mb in sizes_mb(paper):
            n = n_for_mb(mb)
            x = make_array(dist, n, seed=mb)
            t_seq = time_call(lambda: np.sort(x, kind="quicksort"), repeats=3)
            for d_h in dims():
                topo = OHHCTopology(d_h, variant)
                for method in ("paper", "sampled"):
                    r = ohhc_sort_host(x, topo, method=method)
                    s = t_seq / r.t_parallel_model_s
                    out[(variant, dist, mb, d_h, method)] = s
                    emit(
                        f"{fig}/speedup/{variant}/{method}/{dist}/d{d_h}/{mb}MB",
                        r.t_parallel_model_s * 1e6,
                        f"speedup={s:.2f};t_seq_us={t_seq*1e6:.0f}",
                    )
    return out


if __name__ == "__main__":
    run()
