"""sortd serving-layer benchmark (beyond paper, DESIGN.md §8).

Two measurements:

1. **Segmented-vs-loop throughput** — the acceptance gate for the fused
   batch path: for each batch size ``B``, sort the same ``B``
   variable-length arrays (a) with the pre-sortd per-array dispatch loop
   (``SortEngine.sort`` per array: per-request stats, plan, pad, device
   call, transfer) and (b) with ONE fused ``SortEngine.sort_segments``
   call.  The derived field ``ratio_vs_loop`` is loop-time / segmented-time
   (higher is better); the contract is ≥ 2.0 at ``B ≥ 64``.

2. **Service load generation** — drives a live :class:`repro.serve.sortd.Sortd`
   instance in two arrival modes and reports its own metrics:

   * *open-loop*: requests arrive on a fixed schedule at ``--rate`` req/s
     regardless of completion (the "millions of users" shape — arrival rate
     is an input, latency is the output; an overloaded server shows up as a
     growing p99, not a lower throughput);
   * *closed-loop*: ``--clients`` synchronous clients submit → wait →
     repeat (the benchmark-harness shape — throughput is the output and
     latency is bounded by the client count).

   Sizes mix across several shape buckets plus a slice of oversize
   requests (> ``max_bucket``) to exercise the direct fallback.

CSV rows carry p50/p99 latency (µs) and per-bucket pad waste; the full
machine-readable report (the CI artifact) is written as JSON — see
``benchmarks/README.md`` for how to read the columns.
"""

from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks import common
from benchmarks.common import (
    DEFAULT_DTYPE,
    bench_rng,
    emit,
    measure_interleaved,
    resolve_dtype,
)
from repro.core import OHHCTopology, SortEngine
from repro.serve.sortd import Sortd, SortdConfig

LOOP_BATCH_SIZES = (16, 64, 256)
SMOKE_BATCH_SIZES = (16,)
PAPER_BATCH_SIZES = (64, 256, 1024)
LEN_RANGE = (256, 2048)  # per-request key counts for the throughput gate
ROUNDS = 3


def _make_batch(rng, B, dtype, lo=LEN_RANGE[0], hi=LEN_RANGE[1]):
    lens = rng.integers(lo, hi, B)
    return [rng.integers(0, 1 << 30, n).astype(dtype) for n in lens]


def _batch_sizes(paper: bool):
    if common.SMOKE:
        return SMOKE_BATCH_SIZES
    return PAPER_BATCH_SIZES if paper else LOOP_BATCH_SIZES


def _bench_segmented_vs_loop(paper: bool, dtype, report: dict) -> None:
    eng = SortEngine(OHHCTopology(1, "full"))
    rng = bench_rng(7)
    rows = {}
    for B in _batch_sizes(paper):
        arrs = _make_batch(rng, B, dtype)
        lens = [a.size for a in arrs]
        flat = np.concatenate(arrs)
        # warm both paths (compile) + correctness check once
        expect = [np.sort(a) for a in arrs]
        for got in (
            [eng.sort(a) for a in arrs],
            eng.sort_segments(flat, lens),
        ):
            for g, e in zip(got, expect):
                np.testing.assert_array_equal(g, e)
        # interleaved rounds (warmed above), median-of-ROUNDS with IQR —
        # the shared measurement contract (DESIGN.md §9)
        meas = measure_interleaved(
            {
                "loop": lambda: [eng.sort(a) for a in arrs],
                "segmented": lambda: eng.sort_segments(flat, lens),
            },
            warmup=0,
            repeats=ROUNDS,
        )
        t_loop, t_seg = meas["loop"].median_s, meas["segmented"].median_s
        ratio = t_loop / t_seg if t_seg > 0 else float("inf")
        rows[f"B{B}"] = {
            "batch": B,
            "loop_s": t_loop,
            "segmented_s": t_seg,
            "segmented_iqr_s": meas["segmented"].iqr_s,
            "ratio_vs_loop": ratio,
            "keys": int(flat.size),
        }
        emit(
            f"sortd/segmented/B{B}",
            t_seg * 1e6,
            f"ratio_vs_loop={ratio:.2f};loop_us={t_loop*1e6:.0f};"
            f"iqr_us={meas['segmented'].iqr_s * 1e6:.0f}",
        )
    report["throughput"] = rows


def _bench_row_backend_ab(paper: bool, dtype, report: dict) -> None:
    """Forced-plan A/B of the segment row backends through the whole
    ``sort_segments`` serving path (pack → kernel → unpack), not just the
    kernel: ``vmap`` (vmapped XLA sort) vs the fused Pallas batched kernel
    and its 2-op variant (DESIGN.md §8).  The plan is forced per round so
    the measurement is immune to the autotune's own choice.
    """
    from repro.core import SortPlan
    from repro.kernels import ops as kops

    eng = SortEngine(OHHCTopology(1, "full"))
    rng = bench_rng(13)
    B = 16 if common.SMOKE else 64
    arrs = _make_batch(rng, B, dtype, lo=256, hi=1024)
    lens = [a.size for a in arrs]
    flat = np.concatenate(arrs)
    padded_n = kops.bucketed_length(max(lens))
    methods = {"vmap": "bitonic", "pallas": "bitonic_pallas"}
    if np.issubdtype(np.dtype(dtype), np.integer):
        methods["pallas2op"] = "bitonic2op"
    plans = {
        name: SortPlan("sim", m, None, padded_n, "bench row-backend A/B")
        for name, m in methods.items()
    }
    expect = [np.sort(a) for a in arrs]
    for plan in plans.values():  # warm (compile) + correctness check once
        for g, e in zip(eng.sort_segments(flat, lens, plan=plan), expect):
            np.testing.assert_array_equal(g, e)
    meas = measure_interleaved(
        {
            name: (lambda p=plan: eng.sort_segments(flat, lens, plan=p))
            for name, plan in plans.items()
        },
        warmup=0,
        repeats=ROUNDS,
    )
    t_vmap = meas["vmap"].median_s
    rows = {}
    for name, m in meas.items():
        ratio = t_vmap / m.median_s if m.median_s > 0 else float("inf")
        rows[name] = {
            "method": methods[name],
            "median_s": m.median_s,
            "iqr_s": m.iqr_s,
            "vs_vmap": ratio,
        }
        emit(
            f"sortd/rowbackend/{name}/B{B}xL{padded_n}",
            m.median_s * 1e6,
            f"vs_vmap={ratio:.2f};iqr_us={m.iqr_s * 1e6:.0f}",
        )
    report["row_backend_ab"] = {"batch": B, "padded_n": padded_n, "rows": rows}


def _emit_service_metrics(mode: str, m: dict, wall_s: float, n_req: int) -> None:
    emit(
        f"sortd/{mode}/total",
        wall_s / max(n_req, 1) * 1e6,
        f"completed={m['completed']};p50_ms={m['latency_ms']['p50']:.2f};"
        f"p99_ms={m['latency_ms']['p99']:.2f};rps={n_req / wall_s:.0f}",
    )
    for bucket, b in sorted(m["buckets"].items()):
        emit(
            f"sortd/{mode}/{bucket}",
            b["p50_ms"] * 1e3,
            f"p99_ms={b['p99_ms']:.2f};pad_waste={b['pad_waste']:.3f};"
            f"mean_batch={b['mean_batch']:.1f}",
        )


def _request_stream(rng, n_req, dtype, max_bucket):
    """Mixed-size request generator: three bucket classes + ~2% oversize."""
    for i in range(n_req):
        r = rng.random()
        if r < 0.02:
            n = int(rng.integers(max_bucket + 1, max_bucket * 2))
        elif r < 0.50:
            n = int(rng.integers(64, 512))
        elif r < 0.85:
            n = int(rng.integers(512, 2048))
        else:
            n = int(rng.integers(2048, 4096))
        yield rng.integers(0, 1 << 30, n).astype(dtype)


def _bench_service(paper: bool, dtype, arrival: str, rate: float,
                   clients: int, report: dict) -> None:
    cfg = SortdConfig(max_batch=64, max_wait_s=0.005, max_bucket=1 << 12)
    n_req = 600 if paper else (40 if common.SMOKE else 200)
    modes = ("open", "closed") if arrival == "both" else (arrival,)
    for mode in modes:
        eng = SortEngine(OHHCTopology(1, "full"))
        rng = bench_rng(11)
        reqs = list(_request_stream(rng, n_req, dtype, cfg.max_bucket))
        # Warm the per-bucket executables on a throwaway service instance:
        # the engine's jit cache is shared, the measured instance's metrics
        # stay free of warm-up batch-of-1 traffic and compile stalls.
        with Sortd(eng, cfg) as warm:
            for x in reqs[:20]:
                warm.sort(x)
        with Sortd(eng, cfg) as sd:
            t0 = time.perf_counter()
            if mode == "open":
                period = 1.0 / rate
                futs = []
                for i, x in enumerate(reqs):
                    target = t0 + i * period
                    delay = target - time.perf_counter()
                    if delay > 0:
                        time.sleep(delay)
                    futs.append(sd.submit(x))
                outs = [f.result(timeout=120) for f in futs]
            else:
                import threading

                outs = [None] * len(reqs)

                def client(cid):
                    for i in range(cid, len(reqs), clients):
                        outs[i] = sd.submit(reqs[i]).result(timeout=120)

                ts = [
                    threading.Thread(target=client, args=(c,))
                    for c in range(clients)
                ]
                for t in ts:
                    t.start()
                for t in ts:
                    t.join()
            wall = time.perf_counter() - t0
            # spot-check correctness on a slice (full check would dominate)
            for i in range(0, len(reqs), 29):
                np.testing.assert_array_equal(outs[i], np.sort(reqs[i]))
            m = sd.metrics()
        _emit_service_metrics(mode, m, wall, n_req)
        report[mode] = {
            "requests": n_req,
            "wall_s": wall,
            "rps": n_req / wall,
            "rate_target": rate if mode == "open" else None,
            "clients": clients if mode == "closed" else None,
            "metrics": m,
        }


def run(
    paper: bool = False,
    dtype: str = DEFAULT_DTYPE,
    *,
    arrival: str = "both",
    rate: float = 300.0,
    clients: int = 4,
    report: str | None = "sortd_report.json",
) -> dict:
    dt = resolve_dtype(dtype)
    doc: dict = {
        "suite": "sortd",
        "dtype": dtype,
        "config": {"arrival": arrival, "rate": rate, "clients": clients},
    }
    _bench_segmented_vs_loop(paper, dt, doc)
    _bench_row_backend_ab(paper, dt, doc)
    if arrival != "none":
        _bench_service(paper, dt, arrival, rate, clients, doc)
    if report:
        with open(report, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"# sortd report written: {report}", file=sys.stderr)
    return doc


if __name__ == "__main__":
    run(report=sys.argv[1] if len(sys.argv) > 1 else "sortd_report.json")
