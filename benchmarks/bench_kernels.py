"""Kernel microbenchmarks (beyond paper): bitonic local sort and bucket
count/rank vs their jnp oracles — wall time on CPU (interpret mode for the
Pallas path, so the oracle comparison is about correctness-per-cost; on
TPU the kernel path is the fast one)."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, measure_interleaved, time_call
from repro.data.distributions import make_array
from repro.kernels import batched, ops, ref

# Row-backend A/B shapes: the serving buckets the engine's autotune gates
# on (B requests × padded row length).  Smoke shrinks to a wiring check.
ROWSORT_SHAPES = ((64, 1024), (64, 4096))
ROWSORT_SMOKE_SHAPES = ((8, 256),)
# A backend the autotune *selected* may not lose to the best alternative
# by more than this factor in the same interleaved measurement.
ROWSORT_SELECTED_SLACK = 1.25


def _rowsort_ab(paper: bool) -> None:
    """Interleaved A/B of the segment-path row backends (DESIGN.md §8):
    vmapped XLA ``jnp.sort`` vs the fused Pallas batched kernel (both
    compare-exchange variants) on identical full-range int32 batches.

    This is the measured ground the engine's ``choose_row_backend``
    autotune stands on, so the same run re-judges the autotune itself: at
    the gated (non-smoke) shapes, a backend the probe selects that then
    loses the interleaved A/B by more than ``ROWSORT_SELECTED_SLACK``
    fails the benchmark — a selected-but-slower autotune is a bug, not a
    taste difference.
    """
    from repro.core import engine as engine_mod

    interpret = ops._auto_interpret(None)
    shapes = ROWSORT_SMOKE_SHAPES if common.SMOKE else ROWSORT_SHAPES
    for B, L in shapes:
        rng = common.bench_rng(L)
        info = np.iinfo(np.int32)
        x = jnp.asarray(
            rng.integers(info.min, info.max, (B, L), dtype=np.int32)
        )
        lens = jnp.full((B,), L, jnp.int32)
        vmap_fn = jax.jit(jax.vmap(jnp.sort))
        fns = {
            "vmap": lambda: vmap_fn(x),
            "pallas": lambda: batched.batched_row_sort(
                x, lens, method="bitonic", interpret=interpret
            ),
            "pallas2op": lambda: batched.batched_row_sort(
                x, lens, method="bitonic2op", interpret=interpret
            ),
        }
        meas = measure_interleaved(fns, warmup=1, repeats=5)
        t_vmap = meas["vmap"].median_s
        for name, m in meas.items():
            ratio = t_vmap / m.median_s if m.median_s > 0 else float("inf")
            emit(
                f"kernels/rowsort_{name}/B{B}xL{L}",
                m.median_s * 1e6,
                f"vs_vmap={ratio:.2f};iqr_us={m.iqr_s * 1e6:.0f}",
            )
        if common.SMOKE or os.environ.get("REPRO_ROW_BACKEND", "").strip():
            continue  # forced/smoke runs don't judge the autotune
        backend, detail = engine_mod.choose_row_backend(
            L, np.int32, batch_hint=B
        )
        chosen = meas[backend].median_s
        best = min(m.median_s for m in meas.values())
        emit(
            f"kernels/rowsort_autotune/B{B}xL{L}",
            chosen * 1e6,
            f"picked={backend};vs_best={chosen / best:.2f}",
        )
        if chosen > best * ROWSORT_SELECTED_SLACK:
            raise RuntimeError(
                f"row-backend autotune picked {backend!r} at B{B}xL{L} but "
                f"the interleaved A/B has it {chosen / best:.2f}x off the "
                f"best backend (slack {ROWSORT_SELECTED_SLACK}); {detail}"
            )


def run(paper: bool = False) -> None:
    for n in (4096,) if common.SMOKE else (4096, 65536):
        x = jnp.asarray(make_array("random", n, seed=n))
        sort_ref = jax.jit(jnp.sort)
        t_ref = time_call(lambda: sort_ref(x).block_until_ready())
        emit(f"kernels/jnp_sort/{n}", t_ref * 1e6, "oracle")
        t_k = time_call(lambda: ops.local_sort(x).block_until_ready())
        emit(f"kernels/bitonic_interpret/{n}", t_k * 1e6, "pallas-interpret")

    _rowsort_ab(paper)

    m = common.smoke_scaled(65536)
    ids = jnp.asarray(make_array("random", m, seed=1) % 64, jnp.int32)
    t_ref = time_call(
        lambda: jax.jit(ref.ref_bucket_count_rank, static_argnums=1)(ids, 64)[0]
        .block_until_ready()
    )
    emit(f"kernels/count_rank_ref/{m}x64", t_ref * 1e6, "jnp")
    t_k = time_call(lambda: ops.bucket_count_rank(ids, 64)[0].block_until_ready())
    emit(f"kernels/count_rank_pallas/{m}x64", t_k * 1e6, "pallas-interpret")


if __name__ == "__main__":
    run()
