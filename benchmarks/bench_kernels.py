"""Kernel microbenchmarks (beyond paper): bitonic local sort and bucket
count/rank vs their jnp oracles — wall time on CPU (interpret mode for the
Pallas path, so the oracle comparison is about correctness-per-cost; on
TPU the kernel path is the fast one)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, time_call
from repro.data.distributions import make_array
from repro.kernels import ops, ref


def run(paper: bool = False) -> None:
    for n in (4096,) if common.SMOKE else (4096, 65536):
        x = jnp.asarray(make_array("random", n, seed=n))
        sort_ref = jax.jit(jnp.sort)
        t_ref = time_call(lambda: sort_ref(x).block_until_ready())
        emit(f"kernels/jnp_sort/{n}", t_ref * 1e6, "oracle")
        t_k = time_call(lambda: ops.local_sort(x).block_until_ready())
        emit(f"kernels/bitonic_interpret/{n}", t_k * 1e6, "pallas-interpret")

    m = common.smoke_scaled(65536)
    ids = jnp.asarray(make_array("random", m, seed=1) % 64, jnp.int32)
    t_ref = time_call(
        lambda: jax.jit(ref.ref_bucket_count_rank, static_argnums=1)(ids, 64)[0]
        .block_until_ready()
    )
    emit(f"kernels/count_rank_ref/{m}x64", t_ref * 1e6, "jnp")
    t_k = time_call(lambda: ops.bucket_count_rank(ids, 64)[0].block_until_ready())
    emit(f"kernels/count_rank_pallas/{m}x64", t_k * 1e6, "pallas-interpret")


if __name__ == "__main__":
    run()
