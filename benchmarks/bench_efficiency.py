"""Figs 6.12–6.19 — efficiency E = T_S / (P · T_P) for G=P and G=P/2.

Reproduces the paper's findings: efficiency decreases with processor
count (dimension), is nearly size-independent, and is highest for
sorted/reverse-sorted inputs."""

from __future__ import annotations

import numpy as np

from benchmarks.common import dims, emit, n_for_mb, sizes_mb, time_call
from repro.core import OHHCTopology, ohhc_sort_host
from repro.data.distributions import DISTRIBUTIONS, make_array


def run(paper: bool = False, variant: str = "full") -> dict:
    fig = "fig6.12-15" if variant == "full" else "fig6.16-19"
    out = {}
    for dist in DISTRIBUTIONS:
        for mb in sizes_mb(paper):
            n = n_for_mb(mb)
            x = make_array(dist, n, seed=mb)
            t_seq = time_call(lambda: np.sort(x, kind="quicksort"), repeats=3)
            for d_h in dims():
                topo = OHHCTopology(d_h, variant)
                r = ohhc_sort_host(x, topo, method="paper")
                e = t_seq / (topo.total_procs * r.t_parallel_model_s)
                out[(variant, dist, mb, d_h)] = e
                emit(
                    f"{fig}/efficiency/{variant}/{dist}/d{d_h}/{mb}MB",
                    r.t_parallel_model_s * 1e6,
                    f"efficiency={e:.4f};procs={topo.total_procs}",
                )
    return out


if __name__ == "__main__":
    run()
