"""SortEngine dispatch benchmark (beyond paper): autotuned vs fixed methods.

For every input class (the paper's four + duplicate-heavy) and size, times

* ``auto``  — ``SortEngine.sort`` with full stats→dispatch→capacity autotune
  (DESIGN.md §4), and
* ``fixed/<method>`` — the pre-engine calling convention: the same executor
  with a hand-picked method and the legacy ``2·ceil(n/P)`` capacity (the
  engine's overflow-escalation keeps it *correct* on skewed inputs, so the
  fixed baselines pay their recompile/retry cost honestly).

The acceptance bar: ``auto`` within 10% of the best fixed method on every
scenario (it should usually *be* the best fixed method, minus the guessing).
Derived CSV fields carry ``ratio_vs_best_fixed`` per scenario.

Timing: configs are measured round-robin via ``measure_interleaved``
(warm-up drift hits every config equally) and the reported value is the
median of ``ROUNDS`` with the IQR in the derived field — the shared
measurement contract (DESIGN.md §9).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (
    DEFAULT_DTYPE,
    emit,
    measure_interleaved,
    n_for_mb,
    resolve_dtype,
    sizes_mb,
)
from repro.core import OHHCTopology, SortEngine, SortPlan, default_capacity, x64_enabled
from repro.data.distributions import ALL_DISTRIBUTIONS, make_array
from repro.kernels import ops

FIXED_METHODS = ("paper", "sampled")
ROUNDS = 3


def _fixed_plan(eng: SortEngine, n: int, method: str, dtype) -> SortPlan:
    """What callers did before the engine: fixed method, heuristic capacity."""
    if n >= eng.host_threshold or (np.dtype(dtype).itemsize == 8 and not x64_enabled()):
        # 64-bit keys have no exact jit path without x64 — the fixed
        # baseline must take the same host detour the engine does.
        return SortPlan("host", method, None, None, "fixed baseline")
    padded = ops.bucketed_length(n)
    cap = default_capacity(padded, eng.topo.total_procs)
    return SortPlan("sim", method, cap, padded, "fixed baseline")


def run(paper: bool = False, dtype: str = DEFAULT_DTYPE) -> dict:
    topo = OHHCTopology(1, "full")
    eng = SortEngine(topo)
    dt = resolve_dtype(dtype)
    # int32 keeps the historical CSV row names; other dtypes tag the rows.
    tag = "" if dtype == DEFAULT_DTYPE else f"/{dtype}"
    out = {}
    for dist in ALL_DISTRIBUTIONS:
        for mb in sizes_mb(paper):
            n = n_for_mb(mb)
            x = make_array(dist, n, seed=mb, dtype=dt)
            expect = np.sort(x)

            configs = {"auto": None}
            configs.update({m: _fixed_plan(eng, n, m, dt) for m in FIXED_METHODS})
            # warm every executable + check correctness once per config
            retries = {}
            for name, fp in configs.items():
                got = eng.sort(x) if fp is None else eng.sort(x, plan=fp)
                assert np.array_equal(got, expect), (name, dist, mb)
                retries[name] = eng.last_report["overflow_retries"]
                if fp is None:
                    plan = eng.last_report["plan"]
            # interleaved rounds (already warmed above): drift hits every
            # config equally instead of whichever was timed first
            meas = measure_interleaved(
                {
                    name: (lambda fp=fp: eng.sort(x) if fp is None
                           else eng.sort(x, plan=fp))
                    for name, fp in configs.items()
                },
                warmup=0,
                repeats=ROUNDS,
            )
            times = {name: m.median_s for name, m in meas.items()}

            for m in FIXED_METHODS:
                emit(
                    f"engine/fixed-{m}/{dist}/{mb}MB{tag}",
                    times[m] * 1e6,
                    f"path={configs[m].path};retries={retries[m]};"
                    f"iqr_us={meas[m].iqr_s * 1e6:.1f}",
                )
            best = min(times[m] for m in FIXED_METHODS)
            ratio = times["auto"] / best if best > 0 else 1.0
            out[(dist, mb)] = {**times, "ratio": ratio}
            emit(
                f"engine/auto/{dist}/{mb}MB{tag}",
                times["auto"] * 1e6,
                f"path={plan.path};method={plan.method};"
                f"ratio_vs_best_fixed={ratio:.2f};"
                f"iqr_us={meas['auto'].iqr_s * 1e6:.1f}",
            )
    return out


if __name__ == "__main__":
    run()
