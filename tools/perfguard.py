#!/usr/bin/env python
"""Perf-regression gate runner (DESIGN.md §9).

Runs the pinned perf suites (``repro.perf.suites``) under the enforced
timing discipline, normalizes every case against this machine's calibrated
roofline, and gates the normalized ratios against the committed
``benchmarks/baselines/BENCH_<suite>.json`` files exactly the way
``tools/verify.py`` gates conformance: any regression beyond a case's
tolerance — or a new/dropped case — fails the run until the baseline is
explicitly re-recorded.

Usage::

    PYTHONPATH=src python tools/perfguard.py --smoke              # CI gate
    PYTHONPATH=src python tools/perfguard.py --smoke --update-baseline
    PYTHONPATH=src python tools/perfguard.py --full               # nightly
    PYTHONPATH=src python tools/perfguard.py --suite engine --filter dupes
    PYTHONPATH=src python tools/perfguard.py --smoke --slack 2    # shared runner

``--slack`` scales every tolerance arm (CI shared runners are noisy);
``--filter``/``--suite`` subset runs skip the missing-case check, mirroring
verify's subset diff.  ``--report``/``--markdown`` write the CI artifacts.
"""

from __future__ import annotations

import argparse
import datetime
import json
import pathlib
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_BASELINE_DIR = ROOT / "benchmarks" / "baselines"

# Self-contained invocation (`python tools/perfguard.py ...`): make the
# in-repo package importable without requiring PYTHONPATH=src.
if str(ROOT / "src") not in sys.path:
    sys.path.insert(0, str(ROOT / "src"))


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="pinned CI slice (default)")
    mode.add_argument("--full", action="store_true",
                      help="every registered case, nightly scope")
    ap.add_argument("--suite", action="append", default=None,
                    help="run only this suite (repeatable)")
    ap.add_argument("--filter", default=None,
                    help="substring filter on case ids")
    ap.add_argument("--baseline-dir", default=str(DEFAULT_BASELINE_DIR),
                    help=f"BENCH_<suite>.json directory (default {DEFAULT_BASELINE_DIR})")
    ap.add_argument("--update-baseline", action="store_true",
                    help="record outcomes as the new baselines instead of gating")
    ap.add_argument("--slack", type=float, default=1.0,
                    help="tolerance multiplier for noisy hosts (CI uses 2)")
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=5,
                    help="median-of-k repeats per case")
    ap.add_argument("--report", default=None,
                    help="write the JSON report (CI artifact) here")
    ap.add_argument("--markdown", default=None,
                    help="write the markdown report here")
    ap.add_argument("-q", "--quiet", action="store_true")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)
    from repro import perf
    from repro.perf.suites import SUITE_NAMES

    smoke = not args.full
    suites = list(args.suite) if args.suite else list(SUITE_NAMES)
    for s in suites:
        if s not in SUITE_NAMES:
            print(f"unknown suite {s!r}; choose from {SUITE_NAMES}")
            return 2
    if args.update_baseline and args.filter:
        # A --filter run measures a slice of a suite; recording it would
        # silently shrink the committed baseline out from under CI.
        print("refusing --update-baseline with --filter: record whole "
              "suites (optionally narrowed with --suite)")
        return 2
    if (args.update_baseline and smoke
            and pathlib.Path(args.baseline_dir).resolve()
            == DEFAULT_BASELINE_DIR.resolve()):
        # Committed baselines carry the full case set (--smoke gates a
        # pinned subset of them); a smoke recording would drop the
        # full-only cases from the committed files.
        print("refusing --update-baseline in --smoke mode: the committed "
              "baselines are recorded at --full scope; pass --full, or "
              "--baseline-dir PATH to record a smoke set elsewhere")
        return 2

    hw = perf.host_hw()
    if not args.quiet:
        print(f"# hw: {hw.name}  mem_bw={hw.hbm_bw / 1e9:.1f}GB/s  "
              f"gemm={hw.peak_bf16_flops / 1e9:.1f}GFLOP/s  "
              f"mode={'smoke' if smoke else 'full'}  slack={args.slack:g}x")

    baseline_dir = pathlib.Path(args.baseline_dir)
    t0 = time.perf_counter()
    suite_records: dict = {}
    suite_verdicts: dict = {}
    rc = 0
    for suite in suites:
        def progress(rec):
            if not args.quiet:
                pct = ("-" if rec.pct_of_roofline is None
                       else f"{rec.pct_of_roofline:.2f}%")
                print(f"  {rec.case_id}: {rec.median_s * 1e6:.0f}us "
                      f"(iqr {rec.iqr_s * 1e6:.0f}us, roofline {pct}, "
                      f"norm_ratio {rec.norm_ratio:.3g})", flush=True)

        records = perf.run_suite(
            suite, smoke=smoke, hw=hw, warmup=args.warmup,
            repeats=args.repeats, case_filter=args.filter, progress=progress,
        )
        suite_records[suite] = records
        path = perf.baseline_path(suite, baseline_dir)
        if args.update_baseline:
            trajectory = None
            if path.exists():
                trajectory = perf.load_baseline(path).get("trajectory")
            doc = perf.build_baseline(
                records, suite=suite, hw_name=hw.name,
                recorded_utc=datetime.datetime.now(datetime.timezone.utc)
                .isoformat(timespec="seconds"),
                trajectory=trajectory,
            )
            perf.save_baseline(doc, path)
            print(f"baseline recorded: {path} ({len(records)} cases)")
            continue
        baseline = perf.load_baseline(path) if path.exists() else None
        # Committed baselines are recorded at --full scope; a --smoke run
        # measures its pinned slice of them, so missing cases are expected
        # there (subset diff) but a dropped case in a --full run fails.
        verdicts = perf.judge(
            records, baseline, subset=bool(args.filter) or smoke,
            slack=args.slack,
        )
        suite_verdicts[suite] = verdicts
        for v in verdicts:
            if v.status != "pass":
                print(f"{v.status.upper():7s} {v.case_id}: {v.detail}")
        if baseline is None:
            print(f"baseline MISSING: {path} — the perf gate cannot run; "
                  "restore the committed file or record with --update-baseline")
        if not perf.gate_ok(verdicts) or baseline is None:
            rc = 1

    elapsed = time.perf_counter() - t0
    if args.update_baseline:
        return 0

    if args.markdown:
        pathlib.Path(args.markdown).write_text(
            perf.markdown_report(suite_verdicts, hw_name=hw.name, slack=args.slack)
        )
    if args.report:
        pathlib.Path(args.report).write_text(json.dumps(
            perf.json_report(
                suite_verdicts, suite_records, hw_name=hw.name,
                slack=args.slack, elapsed_s=elapsed,
            ),
            indent=1,
        ) + "\n")

    totals = perf.summarize([v for vs in suite_verdicts.values() for v in vs])
    print(f"perfguard[{'smoke' if smoke else 'full'}]: "
          + ", ".join(f"{k}={n}" for k, n in totals.items())
          + f", {elapsed:.1f}s — {'OK' if rc == 0 else 'GATE FAILED'}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
