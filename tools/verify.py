#!/usr/bin/env python
"""Paper-grid conformance runner (DESIGN.md §7).

Sweeps the verify grid (``repro.verify.grid``), checks every scenario
against the ``np.sort`` oracle plus cross-path agreement, runs the
metamorphic/fault property battery on a representative slice, and gates
the result on the committed baseline (``tests/baselines/verify_smoke.json``)
— any plan/capacity/status drift fails the run until the baseline is
explicitly re-recorded.

Usage::

    PYTHONPATH=src python tools/verify.py --smoke              # CI gate
    PYTHONPATH=src python tools/verify.py --smoke --update-baseline
    PYTHONPATH=src python tools/verify.py --full --devices 6   # nightly
    PYTHONPATH=src python tools/verify.py --smoke --filter uint32

``--devices N`` forces N XLA host devices (set *before* jax imports) so
the ``dist`` scenarios become runnable on a single machine.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_BASELINE = ROOT / "tests" / "baselines" / "verify_smoke.json"

# Self-contained invocation (`python tools/verify.py ...`): make the
# in-repo package importable without requiring PYTHONPATH=src.
if str(ROOT / "src") not in sys.path:
    sys.path.insert(0, str(ROOT / "src"))


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true", help="pruned CI grid (default)")
    mode.add_argument("--full", action="store_true", help="the whole paper grid")
    mode.add_argument("--tier1", action="store_true", help="the fast pytest subset")
    mode.add_argument("--sortd", action="store_true",
                      help="sortd serving-layer smoke slice (DESIGN.md §8): "
                      "live micro-batching service vs the np.sort oracle")
    mode.add_argument("--degraded", action="store_true",
                      help="degraded-topology slice only (DESIGN.md §11): "
                      "the fault grid + fault properties, drift-gated "
                      "against the committed smoke baseline")
    ap.add_argument("--devices", type=int, default=1,
                    help="XLA host device count (>1 unlocks dist scenarios)")
    ap.add_argument("--filter", default=None,
                    help="substring filter on scenario ids")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline JSON (default for --smoke: {DEFAULT_BASELINE})")
    ap.add_argument("--update-baseline", action="store_true",
                    help="record outcomes as the new baseline instead of gating")
    ap.add_argument("--report", default=None,
                    help="write the full JSON report (CI artifact) here")
    ap.add_argument("--skip-properties", action="store_true",
                    help="grid only; skip the metamorphic/fault battery")
    ap.add_argument("-q", "--quiet", action="store_true")
    return ap.parse_args(argv)


def run_sortd_slice(args) -> int:
    """Serving-layer smoke: a live sortd instance must agree with np.sort.

    Submits a (dtype × distribution × size) request grid — including
    oversize requests beyond the largest coalescible bucket — from two
    concurrent client threads, checks every result against the oracle, and
    sanity-checks the service's own accounting (completion count, flush
    reasons, per-bucket latency/pad-waste invariants).
    """
    import threading
    import numpy as np

    from repro.core import SortEngine
    from repro.data.distributions import make_array
    from repro.serve.sortd import Sortd, SortdConfig

    cfg = SortdConfig(max_batch=32, max_wait_s=0.005, max_bucket=1 << 12)
    eng = SortEngine()
    cases = []
    seed = 0
    for dtype in ("int32", "int16", "uint32", "float32"):
        for dist in ("random", "sorted", "dupes", "local"):
            for n in (37, 513, 2048):
                seed += 1
                cases.append(
                    (f"{dtype}/{dist}/{n}",
                     make_array(dist, n, seed=seed, dtype=np.dtype(dtype)))
                )
    # oversize → the direct per-array engine path
    cases.append(("int32/random/oversize",
                  make_array("random", (1 << 12) + 777, seed=99)))

    t0 = time.perf_counter()
    fails = []
    with Sortd(eng, cfg) as sd:
        futs = [None] * len(cases)

        def submit_range(lo, hi):
            for i in range(lo, hi):
                futs[i] = sd.submit(cases[i][1])

        mid = len(cases) // 2
        threads = [
            threading.Thread(target=submit_range, args=(0, mid)),
            threading.Thread(target=submit_range, args=(mid, len(cases))),
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for (name, x), fut in zip(cases, futs):
            try:
                out = fut.result(timeout=120)
            except Exception as e:  # noqa: BLE001 - report, don't crash the slice
                fails.append((name, f"raised {e!r}"))
                continue
            if not np.array_equal(out, np.sort(x)):
                fails.append((name, "result != np.sort oracle"))
        m = sd.metrics()

    if m["completed"] != len(cases):
        fails.append(("metrics", f"completed {m['completed']} != {len(cases)}"))
    if m["oversize_direct"] < 1:
        fails.append(("metrics", "oversize request did not take the direct path"))
    if sum(m["flushes"].values()) < 1:
        fails.append(("metrics", "no flush recorded"))
    for bucket, b in m["buckets"].items():
        if not (0.0 <= b["pad_waste"] < 1.0):
            fails.append((f"bucket {bucket}", f"pad_waste {b['pad_waste']}"))
        if b["p99_ms"] + 1e-9 < b["p50_ms"]:
            fails.append((f"bucket {bucket}", "p99 < p50"))
    elapsed = time.perf_counter() - t0
    if args.report:
        pathlib.Path(args.report).write_text(json.dumps({
            "mode": "sortd",
            "elapsed_s": elapsed,
            "cases": len(cases),
            "fails": [list(f) for f in fails],
            "metrics": m,
        }, indent=1) + "\n")
    print(
        f"verify[sortd]: {len(cases) - len(fails)}/{len(cases)} requests pass, "
        f"{len(m['buckets'])} shape buckets, flushes={m['flushes']}, "
        f"p50={m['latency_ms']['p50']:.1f}ms p99={m['latency_ms']['p99']:.1f}ms, "
        f"{elapsed:.1f}s"
    )
    for name, detail in fails:
        print(f"FAIL {name}: {detail}")
    return 1 if fails else 0


def main(argv=None) -> int:
    args = parse_args(argv)
    if args.sortd:
        return run_sortd_slice(args)
    if args.devices > 1:
        flag = f"--xla_force_host_platform_device_count={args.devices}"
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + flag).strip()

    # jax (via repro) must import *after* XLA_FLAGS is set.
    import numpy as np

    from repro.core import OHHCTopology, SortEngine
    from repro.data.distributions import make_array
    from repro.verify import baseline as bl
    from repro.verify import differential, grid, properties

    mesh_axes = 2 if args.devices >= 4 and args.devices % 2 == 0 else 1
    if args.full:
        mode = "full"
        scenarios = grid.full_grid(devices=args.devices, mesh_axes=mesh_axes)
        segments = grid.segment_smoke_grid()
        faults = grid.fault_grid()
        ops_cells = grid.op_smoke_grid()
    elif args.tier1:
        mode = "tier1"
        scenarios = grid.tier1_grid()
        segments = grid.segment_tier1_grid()
        faults = []
        ops_cells = grid.op_tier1_grid()
    elif args.degraded:
        # The fault slice alone (fast CI lane): its cells are a subset of
        # the committed smoke baseline, so the drift gate still applies.
        mode = "degraded"
        scenarios = []
        segments = []
        faults = grid.fault_grid()
        ops_cells = []
    else:
        mode = "smoke"
        scenarios = grid.smoke_grid(devices=args.devices, mesh_axes=mesh_axes)
        segments = grid.segment_smoke_grid()
        faults = grid.fault_grid()
        ops_cells = grid.op_smoke_grid()
    pruned = grid.pruned_cells(devices=args.devices, mesh_axes=mesh_axes)
    if args.filter:
        scenarios = [sc for sc in scenarios if args.filter in sc.scenario_id]
        segments = [sc for sc in segments if args.filter in sc.scenario_id]
        faults = [sc for sc in faults if args.filter in sc.scenario_id]
        ops_cells = [sc for sc in ops_cells if args.filter in sc.scenario_id]

    baseline_path = pathlib.Path(
        args.baseline
        if args.baseline
        else (DEFAULT_BASELINE if mode in ("smoke", "tier1", "degraded") else "")
        or f"verify_{mode}_baseline.json"
    )
    # The committed smoke baseline records the devices=1 grid; gate against
    # it only when this run executes that same grid (or a filtered/tier1/
    # degraded subset of it) — a multi-device sweep adds dist cells the
    # baseline legitimately doesn't carry, which is coverage, not drift.
    subset_run = bool(args.filter) or mode in ("tier1", "degraded")
    comparable = args.baseline is not None or (
        mode in ("smoke", "tier1", "degraded") and args.devices == 1
    )
    if args.update_baseline and baseline_path.resolve() == DEFAULT_BASELINE.resolve() and (
        subset_run or args.devices != 1 or mode != "smoke"
    ):
        # Never let a partial or differently-configured run silently shrink
        # the committed smoke baseline out from under CI; refuse up front.
        print(
            "refusing --update-baseline: the committed smoke baseline must "
            "be recorded by a plain `--smoke` run (no --filter, --devices 1); "
            "pass --baseline PATH to record elsewhere"
        )
        return 2

    t0 = time.perf_counter()
    done = {"n": 0}
    total = len(scenarios) + len(segments) + len(faults) + len(ops_cells)

    def progress(r):
        done["n"] += 1
        if not args.quiet and (r.status != "pass" or done["n"] % 25 == 0):
            print(
                f"[{done['n']:4d}/{total}] {r.status:4s} "
                f"{r.scenario_id}  {r.detail}",
                flush=True,
            )

    engines = differential.EngineCache(devices=args.devices)
    results = differential.run_grid(
        scenarios, devices=args.devices, progress=progress, engines=engines
    )
    # Segmented-batch cells ride the same result stream: cross_check then
    # asserts byte-agreement between the vmapped row backend and both fused
    # Pallas variants (shared group_id), and the baseline gates their drift.
    results += differential.run_segment_grid(
        segments, progress=progress, engines=engines
    )
    # Degraded-topology cells too (DESIGN.md §11): each topology's healthy
    # cell anchors a cross-check group, so every degraded run and typed
    # host fallback must match its bytes exactly.
    results += differential.run_fault_grid(
        faults, progress=progress, engines=engines
    )
    # Workload-op cells (DESIGN.md §12): top-k / pytree pairs / streaming
    # merge vs their np.partition-style oracles; the full-output ops share
    # cross-check groups with plain sort on the same input.
    results += differential.run_op_grid(
        ops_cells, progress=progress, engines=engines
    )
    mismatches = differential.cross_check(results)
    fails = [r for r in results if r.status != "pass"]

    prop_results = []
    if not args.skip_properties:
        topo = OHHCTopology(1, "full")
        eng = SortEngine(topo)
        if mode != "degraded":  # the fault lane runs only the fault battery
            for dist in ("random", "sorted", "dupes", "local"):
                for dtype in ("int32", "uint32"):
                    x = make_array(dist, 1024, seed=11, dtype=np.dtype(dtype))
                    prop_results += properties.metamorphic_checks(
                        eng, x, subject=f"{dtype}/{dist}"
                    )
            keys = make_array("dupes", 500, seed=5)
            prop_results += properties.pairs_pairing_check(
                eng, keys, np.arange(keys.size, dtype=np.int32), subject="int32/dupes"
            )
        x = make_array("local", 2048, seed=9)
        prop_results += properties.fault_replay_for_engine_run(eng, x)
        for d_h in (1, 2):
            t = OHHCTopology(d_h, "full")
            prop_results += properties.fault_replay(
                t, [17] * t.total_procs, groups=(1,)
            )
    prop_fails = [p for p in prop_results if p.status != "pass"]

    doc = bl.build_baseline(results, grid=mode)
    drift = None
    baseline_missing = False
    if args.update_baseline:
        bl.save_baseline(doc, baseline_path)
        print(f"baseline recorded: {baseline_path} ({len(results)} scenarios)")
    elif comparable:
        if baseline_path.exists():
            drift = bl.diff_baselines(
                doc, bl.load_baseline(baseline_path),
                ignore_missing_in_current=subset_run,
            )
        else:
            # The gate is the point: a comparable run with no baseline to
            # gate against must fail loudly, not silently pass (e.g. the
            # committed file lost in a bad merge).
            baseline_missing = True

    elapsed = time.perf_counter() - t0
    if args.report:
        report = {
            "mode": mode,
            "devices": args.devices,
            "elapsed_s": elapsed,
            "scenario_count": len(results),
            "pruned_count": len(pruned),
            "fails": [
                {"scenario": r.scenario_id, "detail": r.detail} for r in fails
            ],
            "cross_check_mismatches": mismatches,
            "property_checks": [dataclass_dict(p) for p in prop_results],
            "pruned": [
                {"scenario": sc.scenario_id, "reason": reason}
                for sc, reason in pruned
            ],
            "drift": None if drift is None else {
                "clean": drift.clean,
                "added": list(drift.added),
                "removed": list(drift.removed),
                "changed": [list(c) for c in drift.changed],
            },
            "baseline": doc,
        }
        pathlib.Path(args.report).write_text(json.dumps(report, indent=1) + "\n")

    print(
        f"verify[{mode}]: {len(results) - len(fails)}/{len(results)} scenarios pass, "
        f"{len(pruned)} cells pruned, {len(mismatches)} cross-check mismatches, "
        f"{len(prop_results) - len(prop_fails)}/{len(prop_results)} property checks "
        f"pass, {elapsed:.1f}s"
    )
    rc = 0
    if fails or mismatches or prop_fails:
        for r in fails:
            print(f"FAIL {r.scenario_id}: {r.detail}")
        for m in mismatches:
            print(f"CROSS-CHECK {m}")
        for p in prop_fails:
            print(f"PROPERTY {p.check}[{p.subject}]: {p.detail}")
        rc = 1
    if drift is not None:
        if drift.clean:
            print(f"baseline: no drift vs {baseline_path}")
        else:
            print(f"baseline DRIFT vs {baseline_path} "
                  "(re-record with --update-baseline if intended):")
            print(drift.summary())
            rc = 1
    elif baseline_missing:
        print(
            f"baseline MISSING: {baseline_path} — the drift gate cannot run; "
            "restore the committed file or re-record with --update-baseline"
        )
        rc = 1
    elif not args.update_baseline:
        print(
            "baseline: not gated (grid config differs from the committed "
            "devices=1 smoke baseline; pass --baseline to compare anyway)"
        )
    return rc


def dataclass_dict(p):
    import dataclasses

    return dataclasses.asdict(p)


if __name__ == "__main__":
    sys.exit(main())
