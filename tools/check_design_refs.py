#!/usr/bin/env python
"""Docs contract check: every ``DESIGN.md §n`` reference must resolve.

Scans ``src/``, ``tests/``, ``benchmarks/``, ``examples/``, and ``tools/``
for ``DESIGN.md §<n>`` citations and verifies a ``§<n>`` section heading
exists in ``DESIGN.md``.  Exits non-zero listing any dangling references
(CI runs this; ``tests/test_docs_refs.py`` runs it under pytest too).
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
REF_RE = re.compile(r"DESIGN\.md\s*§(\d+)")
HEADING_RE = re.compile(r"^#+\s*§(\d+)\b", re.MULTILINE)


def defined_sections() -> set[int]:
    design = ROOT / "DESIGN.md"
    if not design.exists():
        return set()
    return {int(m) for m in HEADING_RE.findall(design.read_text())}


def find_references() -> list[tuple[str, int, int]]:
    """All (relative path, line number, section) citations in the tree."""
    refs = []
    for d in SCAN_DIRS:
        for path in sorted((ROOT / d).rglob("*.py")):
            for lineno, line in enumerate(
                path.read_text(errors="replace").splitlines(), 1
            ):
                for m in REF_RE.finditer(line):
                    refs.append(
                        (str(path.relative_to(ROOT)), lineno, int(m.group(1)))
                    )
    return refs


def main() -> int:
    sections = defined_sections()
    refs = find_references()
    dangling = [(p, ln, s) for p, ln, s in refs if s not in sections]
    if not sections:
        print("check_design_refs: DESIGN.md missing or has no § headings")
        return 1
    if dangling:
        for p, ln, s in dangling:
            print(f"DANGLING: {p}:{ln} cites DESIGN.md §{s} (not defined)")
        print(
            f"check_design_refs: {len(dangling)} dangling of {len(refs)} refs; "
            f"defined sections: {sorted(sections)}"
        )
        return 1
    print(
        f"check_design_refs: OK — {len(refs)} references, "
        f"all resolve to sections {sorted(sections)}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
