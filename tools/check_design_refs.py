#!/usr/bin/env python
"""Docs contract check (stdlib-only — CI's no-deps docs lane runs it).

Three checks, all exiting non-zero with a listing on failure:

1. **Section references**: every ``DESIGN.md §n`` citation under ``src/``,
   ``tests/``, ``benchmarks/``, ``examples/``, and ``tools/`` must resolve
   to a ``§<n>`` heading in ``DESIGN.md``.
2. **Symbol coverage**: every section in ``SYMBOL_SECTIONS`` must mention
   the full public surface it owns — the module's ``__all__`` (parsed
   with ``ast``, so new exports automatically demand coverage) plus
   listed extras.  Currently §2 ↔ ``repro.kernels.batched`` (fused
   batched row sort), §8 ↔ ``repro.serve.sortd`` (serving layer),
   §9 ↔ ``repro.perf`` (perf gate), §10 ↔ ``repro.serve.fleet``
   (multi-worker serving), §11 ↔ ``repro.net.faults`` (degraded
   serving), and §12 ↔ ``repro.core.workloads`` (engine workload ops).
3. **Intra-repo markdown links**: every relative ``[text](target)`` link
   in the top-level docs, ``docs/``, and ``benchmarks/README.md`` must
   point at an existing file (external ``http(s)``/``mailto`` links and
   pure ``#anchor`` links are skipped; ``#fragment`` suffixes are stripped
   before the existence check).

``tests/test_docs_refs.py`` runs the same script under pytest.
"""

from __future__ import annotations

import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
REF_RE = re.compile(r"DESIGN\.md\s*§(\d+)")
HEADING_RE = re.compile(r"^#+\s*§(\d+)\b", re.MULTILINE)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

# Markdown files whose intra-repo links the docs contract covers.
MD_FILES = (
    "README.md",
    "DESIGN.md",
    "PAPER.md",
    "ROADMAP.md",
    "benchmarks/README.md",
)
MD_GLOBS = ("docs/*.md",)

# Sections that own a public API surface: DESIGN.md §<n> must mention
# every name in the module's ``__all__`` (parsed with ``ast``, so a new
# export without documentation fails this check) plus the listed extras.
SYMBOL_SECTIONS = {
    2: (
        "src/repro/kernels/batched.py",  # fused batched row sort
        (
            "local_sort_pairs",
            "sort_pairs_tile_tagged",
            "bucket_count_rank",
        ),
    ),
    8: (
        "src/repro/serve/sortd.py",  # serving layer
        (
            "sort_segments",
            "sort_many",
            "plan_segments",
            "estimate_batch_stats",
            "choose_batch_plan",
            "SEGMENT_BITONIC_MAX",
            "pack_segments",
            "unpack_segments",
            "ROW_BACKENDS",
            "choose_row_backend",
            "REPRO_ROW_BACKEND",
            "SegmentScenario",
        ),
    ),
    9: (
        "src/repro/perf/__init__.py",  # perf gate
        (
            "calibrate_host",
            "bound_time_s",
            "set_smoke",
            "TRAJECTORY_KEEP",
            "WARN_FRACTION",
        ),
    ),
    10: (
        "src/repro/serve/fleet/__init__.py",  # multi-worker serving
        (
            "request_mix",
            "drive_closed_loop",
            "drive_open_loop",
            "worker_down",
            "idle_flush_s",
        ),
    ),
    11: (
        "src/repro/net/faults.py",  # degraded serving
        (
            "set_fault_scenario",
            "apply_fault_scenario",
            "fault_slowdown",
            "is_degraded",
            "optical_link_down",
            "group_uplinks_down",
            "random_links",
            "worker_down",
            "degraded_flushes",
            "fault_grid",
        ),
    ),
    12: (
        "src/repro/core/workloads.py",  # engine workload ops
        (
            "top_k",
            "plan_top_k",
            "merge_sorted",
            "sort_pairs",
            "argsort_keys",
            "argsort",
            "submit_merge",
            "merge",
            "OpScenario",
            "op_smoke_grid",
            "op_tier1_grid",
            "run_op_grid",
            "run_op_scenario",
        ),
    ),
}


def defined_sections() -> set[int]:
    design = ROOT / "DESIGN.md"
    if not design.exists():
        return set()
    return {int(m) for m in HEADING_RE.findall(design.read_text())}


def find_references() -> list[tuple[str, int, int]]:
    """All (relative path, line number, section) citations in the tree."""
    refs = []
    for d in SCAN_DIRS:
        for path in sorted((ROOT / d).rglob("*.py")):
            for lineno, line in enumerate(
                path.read_text(errors="replace").splitlines(), 1
            ):
                for m in REF_RE.finditer(line):
                    refs.append(
                        (str(path.relative_to(ROOT)), lineno, int(m.group(1)))
                    )
    return refs


def module_all(py_path: pathlib.Path) -> list[str]:
    """``__all__`` of a module via ast — no import, no dependencies."""
    tree = ast.parse(py_path.read_text())
    for node in tree.body:
        if isinstance(node, ast.Assign) and any(
            isinstance(t, ast.Name) and t.id == "__all__" for t in node.targets
        ):
            return list(ast.literal_eval(node.value))
    return []


def section_text(number: int) -> str:
    """Body of DESIGN.md section §<number> (heading to next § heading)."""
    text = (ROOT / "DESIGN.md").read_text()
    starts = [
        (int(m.group(1)), m.start())
        for m in re.finditer(r"^#+\s*§(\d+)\b", text, re.MULTILINE)
    ]
    for i, (num, start) in enumerate(starts):
        if num == number:
            end = starts[i + 1][1] if i + 1 < len(starts) else len(text)
            return text[start:end]
    return ""


def check_symbol_coverage() -> list[str]:
    problems = []
    for section, (module, extras) in sorted(SYMBOL_SECTIONS.items()):
        path = ROOT / module
        if not path.exists():
            problems.append(f"symbol coverage: {module} missing")
            continue
        exported = module_all(path)
        if not exported:
            problems.append(f"symbol coverage: {module} has no __all__")
        body = section_text(section)
        if not body:
            problems.append(
                f"symbol coverage: DESIGN.md has no §{section} section"
            )
            continue
        for sym in tuple(exported) + tuple(extras):
            if not re.search(rf"\b{re.escape(sym)}\b", body):
                problems.append(
                    f"UNDOCUMENTED: DESIGN.md §{section} does not mention "
                    f"`{sym}` (public symbol of {module})"
                )
    return problems


def md_files() -> list[pathlib.Path]:
    out = [ROOT / f for f in MD_FILES if (ROOT / f).exists()]
    for g in MD_GLOBS:
        out.extend(sorted(ROOT.glob(g)))
    return out


def check_markdown_links() -> list[str]:
    problems = []
    for md in md_files():
        for lineno, line in enumerate(md.read_text().splitlines(), 1):
            for m in LINK_RE.finditer(line):
                target = m.group(1)
                if target.startswith(("http://", "https://", "mailto:", "#")):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                if not (md.parent / rel).exists():
                    problems.append(
                        f"BROKEN LINK: {md.relative_to(ROOT)}:{lineno} → "
                        f"{target} (no such file)"
                    )
    return problems


def main() -> int:
    sections = defined_sections()
    refs = find_references()
    dangling = [(p, ln, s) for p, ln, s in refs if s not in sections]
    if not sections:
        print("check_design_refs: DESIGN.md missing or has no § headings")
        return 1
    problems = []
    if dangling:
        for p, ln, s in dangling:
            problems.append(f"DANGLING: {p}:{ln} cites DESIGN.md §{s} (not defined)")
    problems += check_symbol_coverage()
    problems += check_markdown_links()
    if problems:
        for p in problems:
            print(p)
        print(
            f"check_design_refs: {len(problems)} problems "
            f"({len(dangling)} dangling of {len(refs)} refs; "
            f"defined sections: {sorted(sections)})"
        )
        return 1
    covered = ", ".join(f"§{n}" for n in sorted(SYMBOL_SECTIONS))
    print(
        f"check_design_refs: OK — {len(refs)} § references resolve to sections "
        f"{sorted(sections)}, {covered} cover their public symbols, "
        f"{len(md_files())} markdown files link-checked"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
