"""repro.verify: grid shape/pruning, tier-1 differential slice vs the
committed smoke baseline, metamorphic properties (permutation for every
distribution — satellite of ISSUE 3), fault replay, baseline drift."""

import dataclasses
import pathlib

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import OHHCTopology, SortEngine
from repro.data.distributions import ALL_DISTRIBUTIONS, make_array
from repro.verify import (
    DriftReport,
    Scenario,
    build_baseline,
    cross_check,
    diff_baselines,
    fault_replay,
    load_baseline,
    metamorphic_checks,
    pairs_pairing_check,
    prune_reason,
    run_grid,
    save_baseline,
    smoke_grid,
    tier1_grid,
)
from repro.verify.properties import fault_replay_for_engine_run

pytestmark = pytest.mark.conformance

BASELINE_PATH = pathlib.Path(__file__).parent / "baselines" / "verify_smoke.json"

# One engine per topology for the whole module: the warm jit cache is part
# of what the conformance battery exercises.
ENGINE = SortEngine(OHHCTopology(1, "full"))


# ------------------------------------------------------------------ grid
def test_smoke_grid_is_big_unique_and_runnable():
    smoke = smoke_grid(devices=1)
    assert len(smoke) >= 100  # the ISSUE's acceptance floor
    ids = [sc.scenario_id for sc in smoke]
    assert len(set(ids)) == len(ids)
    assert all(prune_reason(sc, devices=1) is None for sc in smoke)
    # every axis value the single-device environment can cover is covered
    assert {sc.path for sc in smoke} == {"sim", "host"}
    assert {sc.dist for sc in smoke} == set(ALL_DISTRIBUTIONS)
    assert {sc.d_h for sc in smoke} == {1, 2, 3}
    assert "int64" in {sc.dtype for sc in smoke}  # via the host path


def test_grid_pruning_rules():
    # dist needs a mesh
    sc = Scenario("dist", "sample", "int32", "random", 1024, 1)
    assert prune_reason(sc, devices=1) is not None
    assert prune_reason(sc, devices=4) is None
    # hier needs two mesh axes
    hier = Scenario("dist", "hier", "int32", "random", 1024, 1)
    assert prune_reason(hier, devices=4, mesh_axes=1) is not None
    assert prune_reason(hier, devices=4, mesh_axes=2) is None
    # 64-bit keys only run where they stay 64-bit
    i64 = Scenario("sim", "paper", "int64", "random", 1024, 1)
    assert "64-bit" in prune_reason(i64, devices=1)
    assert prune_reason(dataclasses.replace(i64, path="host"), devices=1) is None
    # invalid method/path combos are named, not crashed on
    assert "invalid" in prune_reason(
        Scenario("sim", "hier", "int32", "random", 1024, 1)
    )


def test_tier1_is_subset_of_smoke():
    smoke_ids = {sc.scenario_id for sc in smoke_grid(devices=1)}
    tier1 = tier1_grid()
    assert tier1 and all(sc.scenario_id in smoke_ids for sc in tier1)


# ---------------------------------------------------- differential slice
def test_tier1_slice_passes_and_matches_committed_baseline():
    """The fast conformance gate: every tier-1 cell sorts exactly, paths
    agree pairwise, and the outcomes match the committed smoke baseline
    (so a plan/capacity policy change fails here until the baseline is
    re-recorded — the anti-silent-flip contract)."""
    results = run_grid(tier1_grid())
    fails = [(r.scenario_id, r.detail) for r in results if r.status != "pass"]
    assert not fails, fails
    assert cross_check(results) == []
    doc = build_baseline(results, grid="tier1")
    committed = load_baseline(BASELINE_PATH)
    drift = diff_baselines(doc, committed, ignore_missing_in_current=True)
    assert drift.clean, drift.summary()


# ------------------------------------------------- metamorphic properties
@pytest.mark.parametrize("dist", ALL_DISTRIBUTIONS)
def test_metamorphic_battery_per_distribution(dist):
    x = make_array(dist, 1500, seed=21)
    for r in metamorphic_checks(ENGINE, x, subject=dist):
        assert r.status == "pass", (r.check, r.subject, r.detail)


@given(
    n=st.integers(2, 2500),
    seed=st.integers(0, 10_000),
    dist=st.sampled_from(list(ALL_DISTRIBUTIONS)),
)
@settings(max_examples=15, deadline=None)
def test_sort_output_is_permutation_of_input(n, seed, dist):
    """Satellite: not merely sorted — a permutation (multiset equality)
    for every distribution, so dropped/duplicated elements can't hide."""
    x = make_array(dist, n, seed=seed)
    out = np.asarray(ENGINE.sort(x))
    assert np.all(out[:-1] <= out[1:])
    vx, cx = np.unique(x, return_counts=True)
    vo, co = np.unique(out, return_counts=True)
    assert np.array_equal(vx, vo) and np.array_equal(cx, co)


def test_sort_pairs_pairing_preserved():
    keys = make_array("dupes", 700, seed=3)
    vals = np.arange(keys.size, dtype=np.int32)
    for r in pairs_pairing_check(ENGINE, keys, vals, subject="dupes"):
        assert r.status == "pass", (r.check, r.detail)


# ------------------------------------------------------------ fault stress
def test_fault_replay_with_engine_bucket_loads():
    """Degraded gathers deliver every element of a real engine run's
    bucket distribution, with no simulator-level reroutes left over."""
    x = make_array("local", 2048, seed=9)
    for r in fault_replay_for_engine_run(ENGINE, x):
        assert r.status == "pass", (r.check, r.subject, r.detail)


def test_fault_replay_uniform_d2():
    topo = OHHCTopology(2, "full")
    for r in fault_replay(topo, [13] * topo.total_procs, groups=(1, 5)):
        assert r.status == "pass", (r.check, r.subject, r.detail)


def test_fault_internal_node_raises_gather_impossible():
    from repro.net.faults import FaultScenario, GatherImpossible, degraded_gather_rounds

    topo = OHHCTopology(1, "full")
    with pytest.raises(GatherImpossible):
        degraded_gather_rounds(
            topo, FaultScenario(name="master_down", failed_nodes=((0, 0),))
        )


# ------------------------------------------------------ baseline machinery
def test_baseline_roundtrip_reports_no_drift(tmp_path):
    results = run_grid(tier1_grid()[:6])
    doc = build_baseline(results, grid="unit")
    p = tmp_path / "b.json"
    save_baseline(doc, p)
    drift = diff_baselines(build_baseline(results, grid="unit"), load_baseline(p))
    assert drift.clean and drift.summary() == "no drift"


def test_baseline_drift_is_detected():
    rec = {"status": "pass", "path": "sim", "method": "paper", "capacity": 64, "retries": 0}
    base = {"schema": 1, "scenarios": {"a": dict(rec), "gone": dict(rec)}}
    cur = {
        "schema": 1,
        "scenarios": {"a": {**rec, "capacity": 128}, "new": dict(rec)},
    }
    drift = diff_baselines(cur, base)
    assert not drift.clean
    assert drift.added == ("new",)
    assert drift.removed == ("gone",)
    assert ("a", "capacity", 64, 128) in drift.changed
    # subset mode ignores cells the current run didn't execute
    subset = diff_baselines(
        {"schema": 1, "scenarios": {"a": dict(rec)}}, base,
        ignore_missing_in_current=True,
    )
    assert subset.clean


def test_drift_report_summary_mentions_every_kind():
    d = DriftReport(("x",), ("y",), (("z", "status", "pass", "fail"),))
    s = d.summary()
    assert "ADDED" in s and "REMOVED" in s and "CHANGED" in s
