"""Property tests for the measurement statistics layer (DESIGN.md §9).

Three invariants the perf gate's math must hold regardless of inputs:
the median is permutation-invariant, dispersion is non-negative, and the
regression judgment is invariant under a uniform rescale of the roofline
peaks (i.e. the same run judged on a k×-faster machine — the property
that makes committed ``BENCH_*.json`` baselines portable).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.perf import Workload, classify, measure, median_iqr, normalize
from repro.roofline.hw import HW

from tests._hypothesis_compat import given, settings, st

BASE_HW = HW(
    name="prop-hw",
    peak_bf16_flops=1e10,
    hbm_bw=1e9,
    ici_bw=1e9,
    inter_pod_bw=1e9,
    hbm_bytes=0.0,
)


def _samples(seed: int, size: int) -> np.ndarray:
    # Log-uniform over ~6 decades: timing samples span µs to seconds.
    rng = np.random.default_rng(seed)
    return np.exp(rng.uniform(np.log(1e-6), np.log(1.0), size=size))


@given(seed=st.integers(0, 500), size=st.integers(1, 25))
@settings(max_examples=40, deadline=None)
def test_median_is_permutation_invariant(seed, size):
    s = _samples(seed, size)
    med, iqr = median_iqr(s)
    rng = np.random.default_rng(seed + 1)
    for _ in range(3):
        perm = rng.permutation(s)
        med_p, iqr_p = median_iqr(perm)
        assert med_p == pytest.approx(med, rel=1e-12)
        assert iqr_p == pytest.approx(iqr, rel=1e-12)


@given(seed=st.integers(0, 500), size=st.integers(1, 25))
@settings(max_examples=40, deadline=None)
def test_dispersion_nonnegative_and_median_bounded(seed, size):
    s = _samples(seed, size)
    med, iqr = median_iqr(s)
    assert iqr >= 0.0
    assert s.min() <= med <= s.max()
    if size == 1:
        assert iqr == 0.0  # single repeat: no dispersion by definition


@given(
    seed=st.integers(0, 200),
    k_exp=st.integers(-3, 3),
    lower=st.sampled_from([0.1, 0.5, 0.9]),
    upper=st.sampled_from([0.25, 0.75, 2.0]),
)
@settings(max_examples=60, deadline=None)
def test_judgment_invariant_under_roofline_rescale(seed, k_exp, lower, upper):
    """Rescale every peak by k: both the fresh and the reference norm_ratio
    scale by the same k, so (status, rel) — the gate's entire judgment —
    is unchanged.  This is the portability property of DESIGN.md §9."""
    k = 10.0 ** k_exp
    hw_k = dataclasses.replace(
        BASE_HW,
        name=f"prop-hw-x{k:g}",
        peak_bf16_flops=BASE_HW.peak_bf16_flops * k,
        hbm_bw=BASE_HW.hbm_bw * k,
        ici_bw=BASE_HW.ici_bw * k,
        inter_pod_bw=BASE_HW.inter_pod_bw * k,
    )
    rng = np.random.default_rng(seed)
    w = Workload(
        bytes_moved=float(rng.uniform(1e3, 1e9)),
        flops=float(rng.uniform(0.0, 1e9)),
    )
    ref_s = float(rng.uniform(1e-5, 1e-1))
    val_s = ref_s * float(rng.uniform(0.2, 3.0))

    ratios = [
        (
            normalize(val_s, w, hw)["norm_ratio"],
            normalize(ref_s, w, hw)["norm_ratio"],
        )
        for hw in (BASE_HW, hw_k)
    ]
    # The ratios themselves scale by k...
    assert ratios[1][0] == pytest.approx(ratios[0][0] * k, rel=1e-9)
    assert ratios[1][1] == pytest.approx(ratios[0][1] * k, rel=1e-9)
    # ...and the judgment does not move at all.
    verdicts = [
        classify(v, r, lower=lower, upper=upper) for v, r in ratios
    ]
    assert verdicts[0][0] == verdicts[1][0]
    assert verdicts[0][1] == pytest.approx(verdicts[1][1], rel=1e-9)


@given(warmup=st.integers(0, 3), repeats=st.integers(1, 6))
@settings(max_examples=15, deadline=None)
def test_measure_call_accounting(warmup, repeats):
    """measure() calls fn exactly warmup+repeats times and keeps only the
    post-warmup samples; the median lies inside [min, max]."""
    calls = []

    def fn():
        calls.append(None)
        return None

    m = measure(fn, warmup=warmup, repeats=repeats)
    assert len(calls) == warmup + repeats
    assert len(m.samples_s) == repeats
    assert (m.warmup, m.repeats) == (warmup, repeats)
    assert m.min_s <= m.median_s <= m.max_s
    assert m.iqr_s >= 0.0
