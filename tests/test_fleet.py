"""repro.serve.fleet (DESIGN.md §10): affinity routing, work stealing,
health/failover, chaos kills, and fleet observability.

Routing and health are pure logic tested without threads; the live-fleet
tests drive real worker threads through ``loadgen`` and check the one
contract that matters under chaos: every admitted request resolves
byte-identical to ``np.sort``, no matter which workers die."""

import threading
import time

import numpy as np
import pytest

from repro.serve.fleet import (
    AffinityRouter,
    ChaosConfig,
    FleetConfig,
    FleetDown,
    HealthMonitor,
    SortdFleet,
    rendezvous_worker,
)
from repro.serve.fleet.loadgen import drive_closed_loop, request_mix
from repro.serve.sortd import affinity_key

WORKERS4 = FleetConfig(
    workers=4,
    # fast, deterministic failure detection for tests: the monitor probes
    # every 5ms and a crashed thread is seen on liveness, not heartbeat age
    heartbeat_interval_s=0.005,
    heartbeat_timeout_s=5.0,
)


# ----------------------------------------------------------------- routing
def test_rendezvous_is_deterministic_and_minimally_disruptive():
    live = (0, 1, 2, 3)
    keys = [("int32", 1 << b) for b in range(6, 14)] + [
        ("uint32", 1 << b) for b in range(6, 14)
    ]
    placement = {k: rendezvous_worker(k, live) for k in keys}
    assert placement == {k: rendezvous_worker(k, live) for k in keys}
    # kill worker 2: only keys that lived on 2 may move, and they must
    # land on survivors — everyone else's placement is untouched
    survivors = (0, 1, 3)
    for k, w in placement.items():
        w2 = rendezvous_worker(k, survivors)
        if w != 2:
            assert w2 == w
        else:
            assert w2 in survivors


def test_affinity_holds_until_watermark_then_steals():
    r = AffinityRouter(steal_watermark=4, steal_margin=2)
    live = (0, 1, 2)
    key = affinity_key(np.zeros(1000, np.int32))
    home = r.route(key, live, {0: 0, 1: 0, 2: 0}).worker
    # below the watermark the same key stays home regardless of imbalance
    for depth in range(4):
        d = r.route(key, live, {w: (depth if w == home else 0) for w in live})
        assert (d.worker, d.stolen) == (home, False)
    # at the watermark with an idle thief, the request is stolen
    d = r.route(key, live, {w: (4 if w == home else 0) for w in live})
    assert d.stolen and d.worker != home and d.affine == home
    # ...but NOT when every worker is equally loaded (margin gate: moving
    # the job would just cool a cache without shedding load)
    d = r.route(key, live, {w: 4 for w in live})
    assert (d.worker, d.stolen) == (home, False)


def test_route_with_single_live_worker_never_steals():
    r = AffinityRouter(steal_watermark=1, steal_margin=1)
    d = r.route(("int32", 512), (2,), {2: 10_000})
    assert (d.worker, d.stolen) == (2, False)


# ------------------------------------------------------------------ health
def test_health_monitor_crash_and_stall_verdicts_fire_once():
    dead = []
    mon = HealthMonitor(timeout_s=0.05, on_dead=lambda w, r: dead.append((w, r)))
    alive = {0: True, 1: True}
    beats = {0: time.monotonic(), 1: time.monotonic()}
    for wid in (0, 1):
        mon.register(
            wid, alive=lambda w=wid: alive[w], last_beat=lambda w=wid: beats[w]
        )
    assert mon.check_now() == [] and dead == []
    alive[0] = False  # crash: caught by liveness immediately
    beats[1] -= 1.0  # stall: heartbeat a second stale against a 50ms budget
    verdicts = mon.check_now()
    assert sorted(verdicts) == [(0, "crashed"), (1, "heartbeat-timeout")]
    assert sorted(dead) == [(0, "crashed"), (1, "heartbeat-timeout")]
    assert mon.check_now() == []  # once per worker, ever


# -------------------------------------------------------------- live fleet
def test_fleet_sorts_and_reports_metrics_shape():
    reqs = request_mix(40, seed=7)
    with SortdFleet(WORKERS4) as fleet:
        wall, outs = drive_closed_loop(fleet.submit, reqs, clients=4)
        m = fleet.metrics()
        rep = fleet.report()
    for o, r in zip(outs, reqs):
        np.testing.assert_array_equal(o, np.sort(r))
    f = m["fleet"]
    assert f["admitted"] == f["completed"] == len(reqs)
    assert f["failed"] == 0 and f["live_workers"] == [0, 1, 2, 3]
    assert f["latency_ms"]["p99"] >= f["latency_ms"]["p50"] > 0
    assert set(m["workers"]) == {"0", "1", "2", "3"}
    assert sum(w["completed"] for w in m["workers"].values()) == len(reqs)
    assert rep["subsystem"] == "repro.serve.fleet"
    assert rep["config"]["workers"] == 4 and rep["chaos"] is None


def test_mixed_dtypes_are_isolated_per_affinity_key():
    """int32 and uint32 of one size are distinct keys: they concentrate on
    their (possibly different) affine workers and NEVER share a batch."""
    n = 700
    xs = [
        np.random.default_rng(i).integers(0, 1 << 30, n).astype(
            "int32" if i % 2 else "uint32"
        )
        for i in range(24)
    ]
    with SortdFleet(WORKERS4) as fleet:
        outs = [f.result(timeout=120) for f in [fleet.submit(x) for x in xs]]
        m = fleet.metrics()
    for o, x in zip(outs, xs):
        np.testing.assert_array_equal(o, np.sort(x))
        assert o.dtype == x.dtype
    # per-worker sortd buckets are keyed dtype/bucket: a mixed batch would
    # have to coalesce under one key, which the key itself forbids
    per_key: dict = {}
    for w in m["workers"].values():
        for bucket_key, b in w["sortd"]["buckets"].items():
            per_key[bucket_key] = per_key.get(bucket_key, 0) + b["requests"]
    assert per_key == {"int32/1024": 12, "uint32/1024": 12}
    homes = {
        k: rendezvous_worker(k, (0, 1, 2, 3))
        for k in (("int32", 1024), ("uint32", 1024))
    }
    for key, home in homes.items():
        w = m["workers"][str(home)]["sortd"]["buckets"]
        assert f"{key[0]}/{key[1]}" in w


def test_chaos_kill_mid_load_loses_nothing():
    """The acceptance scenario: 4 workers, closed-loop load, kill one
    mid-load — zero wrong/lost answers, survivors absorb the backlog."""
    reqs = request_mix(120, seed=13)
    chaos = ChaosConfig(name="kill", kill_worker_after=40)
    with SortdFleet(WORKERS4, chaos=chaos) as fleet:
        wall, outs = drive_closed_loop(fleet.submit, reqs, clients=8)
        rep = fleet.report()
    for o, r in zip(outs, reqs):
        np.testing.assert_array_equal(o, np.sort(r))
    f = rep["fleet"]
    victim = rep["chaos"]["killed_worker"]
    assert victim is not None and f["failovers"] == 1
    assert f["live_workers"] == [w for w in range(4) if w != victim]
    assert f["completed"] == len(reqs) and f["failed"] == 0
    assert rep["chaos"]["fault_scenario"] == f"worker{victim}_down"
    assert rep["workers"][str(victim)]["state"] == "dead"
    assert rep["workers"][str(victim)]["dead_reason"] == "crashed"


def test_targeted_kill_readmits_the_victims_backlog():
    """Concentrate one key's traffic on its affine worker, kill exactly
    that worker, and require the re-admission counters to move."""
    from repro.serve.sortd import SortdConfig

    key = affinity_key(np.zeros(900, np.int32))
    victim = rendezvous_worker(key, (0, 1, 2, 3))
    rng = np.random.default_rng(5)
    xs = [rng.integers(0, 1 << 30, 900).astype(np.int32) for _ in range(60)]
    # coalescing-only workers (no idle flush, long deadline): the victim is
    # guaranteed to still HOLD its binned backlog when the kill lands
    cfg = FleetConfig(
        workers=4,
        heartbeat_interval_s=0.005,
        heartbeat_timeout_s=5.0,
        worker_config=SortdConfig(
            max_queue=256, max_wait_s=0.4, block_on_full=False
        ),
    )
    with SortdFleet(cfg) as fleet:
        futs = [fleet.submit(x) for x in xs]
        fleet.kill_worker(victim)
        outs = [f.result(timeout=120) for f in futs]
        m = fleet.metrics()["fleet"]
    for o, x in zip(outs, xs):
        np.testing.assert_array_equal(o, np.sort(x))
    assert m["failovers"] == 1 and m["readmitted"] > 0
    assert victim not in m["live_workers"]


def test_all_workers_dead_fails_fast_with_fleetdown():
    cfg = FleetConfig(workers=1, heartbeat_interval_s=0.005)
    with SortdFleet(cfg) as fleet:
        fut = fleet.submit(np.arange(100, dtype=np.int32)[::-1])
        np.testing.assert_array_equal(
            fut.result(timeout=60), np.arange(100, dtype=np.int32)
        )
        fleet.kill_worker(0)
        deadline = time.monotonic() + 10.0
        while fleet.live_workers() and time.monotonic() < deadline:
            time.sleep(0.005)
        assert fleet.live_workers() == []
        with pytest.raises(FleetDown):
            fleet.submit(np.arange(8, dtype=np.int32))


def test_close_serves_jobs_stranded_on_an_undetected_crash():
    """Kill a worker with the monitor effectively disabled, then close():
    the final inline sweep must still resolve every admitted future."""
    cfg = FleetConfig(workers=2, heartbeat_interval_s=30.0)
    key = affinity_key(np.zeros(600, np.int32))
    victim = rendezvous_worker(key, (0, 1))
    rng = np.random.default_rng(9)
    xs = [rng.integers(0, 1 << 30, 600).astype(np.int32) for _ in range(20)]
    fleet = SortdFleet(cfg)
    try:
        fleet.kill_worker(victim)
        time.sleep(0.05)  # let the kill land before traffic arrives
        futs = [fleet.submit(x) for x in xs]
    finally:
        fleet.close()
    for f, x in zip(futs, xs):
        np.testing.assert_array_equal(f.result(timeout=0), np.sort(x))


def test_fleet_chaos_stall_recovers_via_heartbeat_timeout():
    """A stalled (not crashed) worker: liveness stays true, the heartbeat
    goes stale, failover drains it — answers still exact."""
    key = affinity_key(np.zeros(800, np.int32))
    victim = rendezvous_worker(key, (0, 1, 2, 3))
    cfg = FleetConfig(
        workers=4, heartbeat_interval_s=0.005, heartbeat_timeout_s=0.3
    )
    n_warm = 48
    chaos = ChaosConfig(
        name="stall", stall_worker_ms=1500.0, stall_worker=victim,
        stall_worker_after=n_warm + 1,
    )
    rng = np.random.default_rng(3)
    xs = [rng.integers(0, 1 << 30, 800).astype(np.int32) for _ in range(30)]
    with SortdFleet(cfg, chaos=chaos) as fleet:
        # warm phase: a same-key burst overflows the steal watermark, so
        # every worker compiles this bucket now — a cold compile during the
        # chaos phase would hold the GIL past the heartbeat timeout and
        # fail over bystanders (the documented false-positive regime)
        warm = [
            rng.integers(0, 1 << 30, 800).astype(np.int32)
            for _ in range(n_warm)
        ]
        for f in [fleet.submit(x) for x in warm]:
            f.result(timeout=120)
        # admission n_warm+1 arms the stall; the victim falls asleep at its
        # next tick (≤ heartbeat_interval).  Send the real traffic only
        # once it is stalled, so its share is stuck behind the sleep and
        # must be failed over — not served in the pre-stall window.
        arming = fleet.submit(rng.integers(0, 1 << 30, 800).astype(np.int32))
        time.sleep(0.05)
        futs = [fleet.submit(x) for x in xs]
        outs = [f.result(timeout=120) for f in futs]
        arming.result(timeout=120)
        rep = fleet.report()
    for o, x in zip(outs, xs):
        np.testing.assert_array_equal(o, np.sort(x))
    f = rep["fleet"]
    assert f["failovers"] >= 1 and victim not in f["live_workers"]
    assert rep["workers"][str(victim)]["dead_reason"] == "heartbeat-timeout"
    assert f["readmitted"] >= 1
    assert f["completed"] == n_warm + 1 + len(xs) and f["failed"] == 0
