"""Workload-layer tests (DESIGN.md §12): top-k edges + property sweep,
pytree payload round-trips, streaming merge, the Sortd merge service, and
the MoE argsort-dispatch parity — the satellite battery PR 10 pins."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from benchmarks.common import DTYPES
from repro.core import (
    SortEngine,
    TopKTooLarge,
    autotune_capacity,
    host_bucket_ids,
    merge_sorted_arrays,
    topk_cut,
)
from repro.core import engine as engine_mod
from repro.data.distributions import make_array

# One engine for the module: the op layer shares its jit caches the same
# way the serving layer does, so the suite exercises warm-cache dispatch.
ENG = SortEngine()
P = ENG.topo.total_procs


# --------------------------------------------------------------- top-k edges


def test_top_k_zero_is_empty_and_dtype_preserved():
    x = make_array("random", 100, seed=1, dtype=np.dtype("int16"))
    out = ENG.top_k(x, 0)
    assert out.size == 0 and out.dtype == x.dtype
    assert ENG.last_report["skipped_buckets"] == P


def test_top_k_one_is_min():
    x = make_array("random", 513, seed=2)
    assert ENG.top_k(x, 1)[0] == x.min()


def test_top_k_n_is_full_sort():
    x = make_array("dupes", 300, seed=3)
    np.testing.assert_array_equal(ENG.top_k(x, x.size), np.sort(x))


def test_top_k_too_large_is_typed_error():
    x = make_array("random", 64, seed=4)
    with pytest.raises(TopKTooLarge, match="k=65 exceeds n=64"):
        ENG.top_k(x, 65)
    assert issubclass(TopKTooLarge, ValueError)  # catchable as ValueError


def test_top_k_rejects_non_int_k():
    x = make_array("random", 64, seed=4)
    with pytest.raises(TypeError):
        ENG.top_k(x, True)
    with pytest.raises(TypeError):
        ENG.top_k(x, 2.0)
    with pytest.raises(ValueError):
        ENG.top_k(x, -1)


def test_top_k_on_bucket_boundaries():
    # arange over [0, 8P) → equal-width buckets of exactly 8 elements;
    # k landing on/next to a bucket edge must not drop or duplicate ties.
    x = np.random.default_rng(5).permutation(np.arange(8 * P, dtype=np.int32))
    for k in (7, 8, 9, 16, 8 * P - 1):
        np.testing.assert_array_equal(ENG.top_k(x, k), np.arange(k))


def test_top_k_duplicate_ties_straddling_rank_k():
    x = np.concatenate(
        [np.zeros(10, np.int32), np.full(20, 5, np.int32)]
    )
    rng = np.random.default_rng(6)
    rng.shuffle(x)
    out = ENG.top_k(x, 15)
    np.testing.assert_array_equal(
        out, np.array([0] * 10 + [5] * 5, np.int32)
    )


def test_top_k_plan_reason_reports_skip_accounting():
    x = make_array("random", 2048, seed=7)
    plan = ENG.plan_top_k(x, 32)
    assert "skipped=" in plan.reason and "top_k k=32" in plan.reason


@given(
    dtype=st.sampled_from(DTYPES),
    n=st.integers(0, 400),
    kpct=st.integers(0, 100),
    dist=st.sampled_from(("random", "dupes", "local", "sorted")),
)
@settings(max_examples=60, deadline=None)
def test_top_k_matches_sorted_head_property(dtype, n, kpct, dist):
    x = make_array(dist, n, seed=n + kpct, dtype=np.dtype(dtype))
    k = (n * kpct) // 100
    out = ENG.top_k(x, k)
    np.testing.assert_array_equal(out, np.sort(x)[:k])
    assert out.dtype == x.dtype


def test_host_and_device_bucket_ids_agree_bitwise():
    import jax.numpy as jnp

    for dtype in ("int8", "int16", "int32", "uint32", "float32"):
        x = make_array("random", 257, seed=11, dtype=np.dtype(dtype))
        want = host_bucket_ids(x, P)
        got = np.asarray(
            engine_mod._paper_ids(
                jnp.asarray(x), jnp.ones(x.size, bool), P=P
            )
        )
        np.testing.assert_array_equal(got.astype(np.int64), want, err_msg=dtype)


def test_topk_cut_boundaries():
    counts = np.array([4, 0, 4, 8])
    assert topk_cut(counts, 1) == (1, 3)
    assert topk_cut(counts, 4) == (1, 3)  # k exactly on the first edge
    assert topk_cut(counts, 5) == (3, 1)  # empty bucket can't cover it
    assert topk_cut(counts, 8) == (3, 1)
    assert topk_cut(counts, 9) == (4, 0)
    assert topk_cut(counts, 16) == (4, 0)


# ------------------------------------------------- satellite 4: capacity fix


def test_top_k_plan_does_not_inherit_full_sort_capacity():
    """Red-before/green-after: 1448 duplicates of one huge value force the
    full sort's worst-row capacity to cover that bucket, but a k=64 head
    never touches it — the top-k plan must size capacity from the KEPT
    buckets only and still run overflow-free."""
    from repro.kernels import ops

    x = np.concatenate(
        [
            np.arange(600, dtype=np.int32),
            np.full(1448, np.iinfo(np.int32).max - 1, np.int32),
        ]
    )
    np.random.default_rng(8).shuffle(x)
    stats = ENG.stats(x)
    cap_full = autotune_capacity(
        stats, "paper", P, ops.bucketed_length(x.size)
    )
    assert cap_full >= 1448  # the dupe bucket dominates the full sort

    plan = ENG.plan_top_k(x, 64)
    assert plan.path == "sim", plan.reason
    assert plan.capacity is not None and plan.capacity < cap_full

    out = ENG.top_k(x, 64, plan=plan)
    np.testing.assert_array_equal(out, np.arange(64, dtype=np.int32))
    assert ENG.last_report["overflow_retries"] == 0
    assert ENG.last_report["capacity_used"] == plan.capacity


# ------------------------------------------------------ pytree payload pairs


def _nested_payload(x: np.ndarray):
    n = x.size
    idx = np.arange(n, dtype=np.int64)
    return {
        "idx": idx,
        "nested": (
            x.astype(np.float64),
            ((idx * 7) % 251).astype(np.int8),
        ),
        "mat": np.arange(n * 3, dtype=np.float32).reshape(n, 3),
    }


def test_sort_pairs_pytree_round_trip_byte_exact():
    x = make_array("dupes", 500, seed=9)
    vals = _nested_payload(x)
    ks, out = ENG.sort_pairs(x, vals)
    np.testing.assert_array_equal(np.asarray(ks), np.sort(x))
    perm = np.asarray(out["idx"])
    assert np.array_equal(np.sort(perm), np.arange(x.size))
    for got, src in (
        (out["idx"], vals["idx"]),
        (out["nested"][0], vals["nested"][0]),
        (out["nested"][1], vals["nested"][1]),
        (out["mat"], vals["mat"]),
    ):
        assert np.asarray(got).tobytes() == src[perm].tobytes()
        assert np.asarray(got).dtype == src.dtype


def test_sort_pairs_pytree_shuffle_invariance():
    # Metamorphic: with UNIQUE keys the sorted (key, payload) stream is a
    # function of the multiset only — any input permutation yields
    # byte-identical output.
    rng = np.random.default_rng(10)
    keys = rng.permutation(np.arange(400, dtype=np.int32)) * 3 - 17
    vals = {"a": keys.astype(np.int64) * 5, "b": (keys.astype(np.float32),)}
    ks1, out1 = ENG.sort_pairs(keys, vals)
    sh = rng.permutation(keys.size)
    ks2, out2 = ENG.sort_pairs(
        keys[sh], {"a": vals["a"][sh], "b": (vals["b"][0][sh],)}
    )
    np.testing.assert_array_equal(np.asarray(ks1), np.asarray(ks2))
    assert np.asarray(out1["a"]).tobytes() == np.asarray(out2["a"]).tobytes()
    assert (
        np.asarray(out1["b"][0]).tobytes() == np.asarray(out2["b"][0]).tobytes()
    )


@pytest.mark.parametrize("dtype", ["int32", "uint32", "int16", "float32"])
def test_sort_pairs_pytree_sentinel_ties_keep_payload(dtype):
    # PR-8 regression, now on the pytree path: keys equal to the dtype max
    # collide with the kernel's pad sentinel; their payloads must survive.
    dt = np.dtype(dtype)
    hi = np.finfo(dt).max if dt.kind == "f" else np.iinfo(dt).max
    rng = np.random.default_rng(12)
    keys = make_array("random", 70, seed=12, dtype=dt)
    keys[rng.choice(70, 9, replace=False)] = hi
    vals = {"tag": np.arange(70, dtype=np.int64)}
    ks, out = ENG.sort_pairs(keys, vals)
    ks, tag = np.asarray(ks), np.asarray(out["tag"])
    np.testing.assert_array_equal(ks, np.sort(keys))
    np.testing.assert_array_equal(keys[tag], ks)  # pairing intact
    assert set(tag[ks == hi]) == set(np.flatnonzero(keys == hi))


def test_sort_pairs_flat_path_unchanged():
    # The serving hot path: a single flat 1-D payload must still ride the
    # tagged pair kernel and return jax arrays (warm shape-bucket cache).
    x = make_array("random", 257, seed=13)
    v = np.arange(257, dtype=np.int32)
    ks, vs = ENG.sort_pairs(x, v)
    assert hasattr(ks, "devices") and hasattr(vs, "devices")  # jax arrays
    np.testing.assert_array_equal(np.asarray(ks), np.sort(x))
    np.testing.assert_array_equal(x[np.asarray(vs)], np.asarray(ks))


def test_sort_pairs_pytree_leaf_shape_mismatch_raises():
    x = make_array("random", 64, seed=14)
    with pytest.raises(ValueError, match="leading dim"):
        ENG.sort_pairs(x, {"bad": np.arange(63)})


# ---------------------------------------------------------- streaming merge


@given(
    dtype=st.sampled_from(("int32", "uint32", "int16", "float32", "int64")),
    chunks=st.integers(1, 6),
    seed=st.integers(0, 4),
)
@settings(max_examples=30, deadline=None)
def test_merge_stream_equals_full_resort_property(dtype, chunks, seed):
    # k successive appends == one full re-sort (the §12 streaming contract)
    dt = np.dtype(dtype)
    whole = make_array("random", 257 * chunks + seed, seed=seed, dtype=dt)
    buf = np.empty(0, dt)
    for part in np.array_split(whole, chunks):
        buf = ENG.merge_sorted(buf, part)
    np.testing.assert_array_equal(buf, np.sort(whole))
    assert buf.dtype == dt


def test_merge_sorted_rejects_unsorted_buffer():
    with pytest.raises(ValueError, match="not ascending"):
        ENG.merge_sorted(np.array([3, 1, 2], np.int32), np.array([5], np.int32))


def test_merge_sorted_rejects_dtype_mismatch():
    with pytest.raises(ValueError, match="dtype"):
        ENG.merge_sorted(np.array([1], np.int32), np.array([2], np.int64))


def test_merge_sorted_arrays_tie_and_empty_edges():
    a = np.array([1, 2, 2, 9], np.int32)
    b = np.array([2, 2, 10], np.int32)
    np.testing.assert_array_equal(
        merge_sorted_arrays(a, b), np.sort(np.concatenate([a, b]))
    )
    np.testing.assert_array_equal(merge_sorted_arrays(a, a[:0]), a)
    np.testing.assert_array_equal(merge_sorted_arrays(a[:0], b), b)


def test_sortd_interleaved_merge_and_sort_never_cross_contaminate():
    """The §12 service op: merge and sort requests on the SAME
    (dtype, shape-bucket) must coalesce into separate bins — a merge
    output leaking into a sort batch (or vice versa) is exactly the
    cross-contamination this pins."""
    from repro.serve.sortd import Sortd, SortdConfig

    rng = np.random.default_rng(15)
    cfg = SortdConfig(max_batch=8, max_wait_s=0.02)
    with Sortd(SortEngine(), cfg) as sd:
        futs = []
        for i in range(6):
            x = rng.integers(0, 1 << 20, 400).astype(np.int32)
            buf = np.sort(rng.integers(0, 1 << 20, 300).astype(np.int32))
            new = rng.integers(0, 1 << 20, 400).astype(np.int32)
            futs.append(("sort", x, sd.submit(x)))
            futs.append(("merge", (buf, new), sd.submit_merge(buf, new)))
        for op, arg, fut in futs:
            out = fut.result(timeout=60)
            if op == "sort":
                np.testing.assert_array_equal(out, np.sort(arg))
            else:
                buf, new = arg
                np.testing.assert_array_equal(
                    out, np.sort(np.concatenate([buf, new]))
                )
        m = sd.metrics()
        buckets = set(m["buckets"])
    assert any(b.startswith("merge/int32/") for b in buckets), buckets
    assert any(not b.startswith("merge/") for b in buckets), buckets


def test_sortd_merge_bad_buffer_fails_alone():
    from repro.serve.sortd import Sortd, SortdConfig

    cfg = SortdConfig(max_batch=8, max_wait_s=0.02)
    with Sortd(SortEngine(), cfg) as sd:
        good = sd.submit_merge(
            np.array([1, 5], np.int32), np.array([3, 2], np.int32)
        )
        bad = sd.submit_merge(
            np.array([9, 1], np.int32), np.array([4, 7], np.int32)
        )
        np.testing.assert_array_equal(
            good.result(timeout=60), np.array([1, 2, 3, 5], np.int32)
        )
        with pytest.raises(ValueError, match="ascending"):
            bad.result(timeout=60)
        with pytest.raises(ValueError, match="dtype mismatch"):
            sd.submit_merge(np.array([1], np.int32), np.array([2], np.float32))


# ----------------------------------------------------- MoE dispatch parity


def test_moe_argsort_dispatch_is_bit_identical_to_sorted():
    import jax
    import jax.numpy as jnp

    from repro.configs.base import ModelConfig, MoEConfig
    from repro.models import moe as MOE
    from repro.models.common import NO_SHARD

    cfg = ModelConfig(
        family="moe", d_model=32, num_heads=4, dtype=jnp.float32,
        moe=MoEConfig(
            num_experts=4, num_experts_per_tok=2, expert_d_ff=64,
            dispatch="sorted", capacity_factor=1.25,
        ),
    )
    import dataclasses

    cfg_a = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="argsort"))
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    y1, aux1 = MOE.apply_moe(p, x, cfg, NO_SHARD)
    y2, aux2 = MOE.apply_moe(p, x, cfg_a, NO_SHARD)
    assert np.asarray(y1).tobytes() == np.asarray(y2).tobytes()
    assert np.asarray(aux1).tobytes() == np.asarray(aux2).tobytes()


# --------------------------------------------------- conformance tier1 slice


@pytest.mark.conformance
def test_op_tier1_grid_passes_and_cross_checks():
    from repro.verify import differential, grid

    cells = grid.op_tier1_grid()
    assert cells, "tier1 op slice must not be empty"
    results = differential.run_op_grid(cells)
    fails = [(r.scenario_id, r.detail) for r in results if r.status != "pass"]
    assert not fails, fails
    assert differential.cross_check(results) == []
