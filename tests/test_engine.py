"""SortEngine: dispatch policy, capacity autotune (no overflow), warm
jit cache (no recompiles within a shape bucket), batched entry points."""

import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    InputStats,
    OHHCTopology,
    SortEngine,
    SortPlan,
    autotune_capacity,
    choose_plan,
    default_capacity,
    estimate_stats,
)
from repro.data.distributions import ALL_DISTRIBUTIONS, make_array

TOPO = OHHCTopology(1, "full")  # P = 36


def mk_stats(
    n=4096,
    sortedness=0.0,
    skew=1.3,
    dup_top_frac=0.01,
    f_max_paper=None,
    f_max_sampled=0.04,
    num_buckets=36,
):
    if f_max_paper is None:
        f_max_paper = skew / num_buckets
    return InputStats(
        n=n,
        dtype="int32",
        sample_size=1024,
        sortedness=sortedness,
        skew=skew,
        dup_top_frac=dup_top_frac,
        f_max_paper=f_max_paper,
        f_max_sampled=f_max_sampled,
        num_buckets=num_buckets,
    )


# ---------------------------------------------------------------- policy
def test_policy_uniform_small_goes_sim_paper():
    p = choose_plan(mk_stats(), TOPO)
    assert (p.path, p.method) == ("sim", "paper")
    assert p.capacity is not None and p.padded_n == 4096


def test_policy_skewed_small_goes_sim_sampled():
    p = choose_plan(mk_stats(skew=8.0), TOPO)
    assert (p.path, p.method) == ("sim", "sampled")


def test_policy_duplicate_heavy_forces_paper():
    # no splitter rule splits one repeated value — cheaper rule + capacity
    p = choose_plan(mk_stats(skew=12.0, dup_top_frac=0.4, f_max_paper=0.45), TOPO)
    assert (p.path, p.method) == ("sim", "paper")


def test_policy_huge_goes_host():
    p = choose_plan(mk_stats(n=1 << 21), TOPO)
    assert p.path == "host"


def test_policy_large_skewed_goes_host():
    # ragged host buckets are exact under any splitter, so the cheaper
    # equal-width rule is always the host-path method
    p = choose_plan(mk_stats(n=1 << 17, skew=9.0), TOPO)
    assert (p.path, p.method) == ("host", "paper")


def test_policy_mesh_dispatch():
    # multi-axis mesh → hier, regardless of stats
    p = choose_plan(mk_stats(), TOPO, mesh_devices=8, mesh_axes=("pod", "data"))
    assert (p.path, p.method) == ("dist", "hier")
    # presorted → valiant (two-hop routing beats direct-route send skew)
    p = choose_plan(
        mk_stats(sortedness=0.95), TOPO, mesh_devices=8, mesh_axes=("data",)
    )
    assert (p.path, p.method) == ("dist", "valiant")
    # skewed → sampled splitters
    p = choose_plan(mk_stats(skew=8.0), TOPO, mesh_devices=8, mesh_axes=("data",))
    assert (p.path, p.method) == ("dist", "sample")
    # uniform → faithful paper splitters
    p = choose_plan(mk_stats(), TOPO, mesh_devices=8, mesh_axes=("data",))
    assert (p.path, p.method) == ("dist", "paper")
    # a 1-device mesh is no mesh at all
    p = choose_plan(mk_stats(), TOPO, mesh_devices=1, mesh_axes=("data",))
    assert p.path == "sim"


# ------------------------------------------------------------- autotune
def test_autotune_floor_is_deterministic_for_balanced_inputs():
    caps = {
        autotune_capacity(mk_stats(f_max_paper=f), "paper", 36, 4096)
        for f in (0.01, 0.02, 0.028)
    }
    assert len(caps) == 1  # below the 2/P floor every estimate collapses
    (cap,) = caps
    assert cap >= default_capacity(4096, 36) // 2
    assert cap % 8 == 0


def test_autotune_scales_with_measured_skew():
    cap_hot = autotune_capacity(mk_stats(f_max_paper=0.5), "paper", 36, 4096)
    cap_cold = autotune_capacity(mk_stats(f_max_paper=0.02), "paper", 36, 4096)
    assert cap_hot >= 0.5 * 4096
    assert cap_hot <= 4096
    assert cap_hot > 4 * cap_cold


def test_estimated_labels_match_generator_taxonomy():
    for dist, want in [
        ("random", "random"),
        ("sorted", "sorted"),
        ("reversed", "reversed"),
        ("local", ("local", "dupes")),  # tight cluster can read as either
        ("dupes", "dupes"),
    ]:
        s = estimate_stats(make_array(dist, 50_000, seed=3), num_buckets=36)
        want = (want,) if isinstance(want, str) else want
        assert s.label in want, (dist, s)


# ---------------------------------------------------------- correctness
@pytest.mark.parametrize("dist", ALL_DISTRIBUTIONS)
def test_engine_sort_correct_no_overflow(dist):
    """Acceptance: every input class at 1e5+ sorts exactly, model hits
    capacity on the first try (no overflow retries)."""
    eng = SortEngine(TOPO)
    x = make_array(dist, 200_000, seed=11)
    out = eng.sort(x)
    np.testing.assert_array_equal(out, np.sort(x))
    assert eng.last_report["overflow_retries"] == 0
    assert eng.last_report["counts_sum"] == x.size


@pytest.mark.slow
@pytest.mark.parametrize("dist", ALL_DISTRIBUTIONS)
def test_engine_sort_correct_1e6(dist):
    eng = SortEngine(TOPO)
    x = make_array(dist, 1_000_000, seed=13)
    out = eng.sort(x)
    np.testing.assert_array_equal(out, np.sort(x))
    assert eng.last_report["overflow_retries"] == 0


@given(
    n=st.integers(2, 4000),
    seed=st.integers(0, 10_000),
    dist=st.sampled_from(list(ALL_DISTRIBUTIONS)),
    method=st.sampled_from(["paper", "sampled"]),
)
@settings(max_examples=30, deadline=None)
def test_autotuned_capacity_property(n, seed, dist, method):
    """Property: with autotuned capacity the sim path never loses elements
    — ``counts.sum() == n`` and the output equals the oracle — for either
    method forced on any input class."""
    eng = SortEngine(TOPO)
    x = make_array(dist, n, seed=seed)
    stats = eng.stats(x)
    plan = choose_plan(stats, TOPO)
    if plan.path != "sim" or plan.method != method:
        from repro.kernels import ops

        padded = ops.bucketed_length(n)
        cap = autotune_capacity(stats, method, TOPO.total_procs, padded)
        plan = SortPlan("sim", method, cap, padded, "forced")
    out = eng.sort(x, plan=plan)
    np.testing.assert_array_equal(out, np.sort(x))
    assert eng.last_report["counts_sum"] == n


# --------------------------------------------- bucket-id precision (int)
def test_paper_bucket_ids_exact_above_float32_precision():
    """Regression (ISSUE 3 satellite): float32 bucket-id maths collapses
    adjacent keys above 2^24 onto shared bucket edges.  With integer
    arithmetic the sim path's per-bucket counts must match the exact
    equal-width computation for adversarial large-magnitude uint32 keys."""
    eng = SortEngine(TOPO)
    x = np.uint32(1 << 31) + np.arange(36 * 64, dtype=np.uint32)
    rng = np.random.default_rng(0)
    rng.shuffle(x)
    out = eng.sort(x)
    np.testing.assert_array_equal(out, np.sort(x))
    assert eng.last_report["plan"].path == "sim"
    lo, hi = int(x.min()), int(x.max())
    width = (hi - lo) // 36 + 1
    expected = np.bincount((x.astype(np.int64) - lo) // width, minlength=36)
    np.testing.assert_array_equal(eng.last_report["counts"], expected)


def test_policy_64bit_keys_without_x64_go_host():
    """int64/float64 keys would be silently downcast by jnp.asarray on the
    jit paths; dispatch must route them to the exact numpy host path (and
    the result must still match the oracle for values beyond 2^32)."""
    from repro.core import x64_enabled

    if x64_enabled():  # pragma: no cover - container default is x64 off
        pytest.skip("x64 enabled: every path is exact for 64-bit keys")
    s = dataclasses.replace(mk_stats(), dtype="int64")
    assert choose_plan(s, TOPO).path == "host"
    eng = SortEngine(TOPO)
    x = (np.int64(1) << 40) + np.random.default_rng(1).integers(
        0, 1 << 35, 5000, dtype=np.int64
    )
    out = eng.sort(x)
    assert out.dtype == np.int64
    np.testing.assert_array_equal(out, np.sort(x))
    assert eng.last_report["plan"].path == "host"


# ------------------------------------------------------------- jit cache
def test_no_recompile_within_shape_bucket():
    eng = SortEngine(TOPO)
    for n in (1025, 1400, 1777, 2048):  # all bucket to 2048
        x = make_array("random", n, seed=n)
        np.testing.assert_array_equal(eng.sort(x), np.sort(x))
    assert eng.trace_count == 1, "same-bucket traffic must share one executable"
    eng.sort(make_array("random", 5000, seed=1))  # new bucket (8192)
    assert eng.trace_count == 2


def test_explicit_plan_reuses_executable_across_calls():
    eng = SortEngine(TOPO)
    plan = eng.plan(make_array("random", 1500, seed=0))
    for seed in range(5):
        x = make_array("random", 1500, seed=seed)
        np.testing.assert_array_equal(eng.sort(x, plan=plan), np.sort(x))
    assert eng.trace_count == 1


def test_sort_pairs_bucketed_cache():
    eng = SortEngine(TOPO)
    for B in (5, 17, 40, 100):  # all bucket to 128
        keys = np.random.default_rng(B).integers(0, 1000, B).astype(np.int32)
        ks, order = eng.sort_pairs(keys, np.arange(B, dtype=np.int32))
        ks, order = np.asarray(ks), np.asarray(order)
        assert np.all(np.diff(ks) >= 0)
        np.testing.assert_array_equal(np.sort(order), np.arange(B))
        np.testing.assert_array_equal(keys[order], ks)
    assert eng.trace_count == 1


# --------------------------------------------------------------- batched
def test_sort_many_one_executable_per_batch():
    eng = SortEngine(TOPO)
    xs = [make_array("random", n, seed=n) for n in (300, 900, 1024, 77)]
    outs = eng.sort_many(xs)
    assert len(outs) == len(xs)
    for x, o in zip(xs, outs):
        np.testing.assert_array_equal(o, np.sort(x))
    assert eng.trace_count == 1  # one vmapped trace serves the whole batch


def test_sort_many_mixed_skew_batch():
    eng = SortEngine(TOPO)
    xs = [make_array(d, 2000, seed=5) for d in ALL_DISTRIBUTIONS]
    outs = eng.sort_many(xs)
    for x, o in zip(xs, outs):
        np.testing.assert_array_equal(o, np.sort(x))


def test_serve_order_by_length_uses_engine_cache():
    from repro.serve.engine import SortEngine as _SE  # re-exported dependency

    assert _SE is SortEngine


# ------------------------------------------------------ segmented batches
def test_sort_segments_mixed_lengths_exact():
    eng = SortEngine(TOPO)
    lens = [300, 900, 1024, 77, 0, 1, 2000]
    arrs = [make_array("random", n, seed=n + 1) for n in lens]
    outs = eng.sort_segments(np.concatenate(arrs), lens)
    assert len(outs) == len(arrs)
    for a, o in zip(arrs, outs):
        np.testing.assert_array_equal(o, np.sort(a))
    rep = eng.last_report
    assert rep["batch"] == len(arrs)
    assert rep["overflow_retries"] == 0
    assert rep["pad_cells"] == len(arrs) * 2048 - sum(lens)
    assert rep["batch_padded"] == 8  # batch axis bucketed to the next pow2


def test_sort_segments_every_distribution_rows():
    eng = SortEngine(TOPO)
    xs = [make_array(d, 2000, seed=5) for d in ALL_DISTRIBUTIONS]
    outs = eng.sort_segments(
        np.concatenate(xs), [a.size for a in xs]
    )
    for a, o in zip(xs, outs):
        np.testing.assert_array_equal(o, np.sort(a))
    assert eng.last_report["overflow_retries"] == 0


def test_sort_segments_one_executable_across_batch_and_length_mixes():
    """Both traced axes are bucketed: every (B ≤ 8, len ≤ 1024) mix must
    share one compiled executable."""
    eng = SortEngine(TOPO)
    for B, n in ((3, 1000), (5, 700), (8, 1024), (2, 517), (7, 800)):
        xs = [make_array("random", n, seed=B * 10 + i) for i in range(B)]
        outs = eng.sort_many(xs)
        for a, o in zip(xs, outs):
            np.testing.assert_array_equal(o, np.sort(a))
    assert eng.trace_count == 1


def test_sort_segments_return_padded_stays_on_device():
    import jax

    eng = SortEngine(TOPO)
    xs = [make_array("random", n, seed=n) for n in (300, 900, 1024, 77)]
    lens = [a.size for a in xs]
    out = eng.sort_segments(np.concatenate(xs), lens, return_padded=True)
    assert isinstance(out, jax.Array)
    assert out.shape == (4, 1024)  # batch axis sliced back to B
    host = np.asarray(out)
    for i, (a, n) in enumerate(zip(xs, lens)):
        np.testing.assert_array_equal(host[i, :n], np.sort(a))


def test_sort_segments_sentinel_valued_keys_survive_padding():
    """Keys equal to the dtype max must not be confused with pad cells."""
    eng = SortEngine(TOPO)
    hi = np.iinfo(np.int32).max
    a = np.array([hi, 5, hi, 1, hi], np.int32)
    b = np.array([hi, hi], np.int32)
    outs = eng.sort_segments(np.concatenate([a, b]), [a.size, b.size])
    np.testing.assert_array_equal(outs[0], np.sort(a))
    np.testing.assert_array_equal(outs[1], np.sort(b))


def test_sort_segments_length_mismatch_raises():
    eng = SortEngine(TOPO)
    with pytest.raises(ValueError, match="seg_lens"):
        eng.sort_segments(np.arange(10, dtype=np.int32), [4, 4])
    with pytest.raises(ValueError, match="negative"):
        eng.sort_segments(np.arange(10, dtype=np.int32), [12, -2])


def test_sort_segments_64bit_without_x64_host_fallback():
    from repro.core import x64_enabled

    if x64_enabled():  # pragma: no cover - container default is x64 off
        pytest.skip("x64 enabled: the jit path is exact for 64-bit keys")
    eng = SortEngine(TOPO)
    rng = np.random.default_rng(2)
    xs = [
        (np.int64(1) << 40) + rng.integers(0, 1 << 35, 500, dtype=np.int64)
        for _ in range(3)
    ]
    outs = eng.sort_segments(np.concatenate(xs), [a.size for a in xs])
    for a, o in zip(xs, outs):
        assert o.dtype == np.int64
        np.testing.assert_array_equal(o, np.sort(a))
    assert eng.last_report["plan"].path == "host"
    with pytest.raises(ValueError, match="return_padded"):
        eng.sort_segments(xs[0], [xs[0].size], return_padded=True)


def test_batch_plan_policy_bitonic_vs_bucket():
    from repro.core import SEGMENT_BITONIC_MAX, choose_batch_plan

    # serving-size rows → direct bitonic rows, no capacity, no stats needed
    p = choose_batch_plan(None, 36, 2048)
    assert (p.method, p.capacity) == ("bitonic", None)
    assert choose_batch_plan(None, 36, SEGMENT_BITONIC_MAX).method == "bitonic"
    # big rows → the bucket machinery with worst-row capacity
    big = SEGMENT_BITONIC_MAX * 2
    p = choose_batch_plan(mk_stats(skew=18.0), 36, big)
    assert p.method == "sampled"  # skewed, not duplicate-dominated
    assert p.capacity is not None
    # duplicate-dominated worst row → paper rule, capacity sized to its f̂
    p = choose_batch_plan(
        mk_stats(f_max_paper=0.5, skew=18.0, dup_top_frac=0.5), 36, big
    )
    assert p.method == "paper"
    assert p.capacity is not None and p.capacity >= 0.5 * big
    with pytest.raises(ValueError, match="stats"):
        choose_batch_plan(None, 36, big)


def test_batch_plan_row_backend_mapping():
    from repro.core import ROW_BACKENDS, choose_batch_plan

    want = {"vmap": "bitonic", "pallas": "bitonic_pallas", "pallas2op": "bitonic2op"}
    for backend in ROW_BACKENDS:
        p = choose_batch_plan(None, 36, 1024, row_backend=backend)
        assert p.method == want[backend]
        assert p.capacity is None
        assert f"row_backend={backend}" in p.reason
    with pytest.raises(ValueError, match="row_backend"):
        choose_batch_plan(None, 36, 1024, row_backend="cuda")


def test_choose_row_backend_env_and_probe(monkeypatch):
    from repro.core import ROW_BACKENDS, choose_row_backend
    from repro.core import engine as engine_mod

    # env override wins and skips the probe
    monkeypatch.setenv("REPRO_ROW_BACKEND", "pallas2op")
    backend, detail = choose_row_backend(256, np.int32)
    assert backend == "pallas2op" and "forced" in detail
    monkeypatch.setenv("REPRO_ROW_BACKEND", "cuda")
    with pytest.raises(ValueError, match="REPRO_ROW_BACKEND"):
        choose_row_backend(256, np.int32)
    # the measured head-to-head: runs all candidates, caches per
    # (padded_n, dtype, probe batch), returns a record for SortPlan.reason
    monkeypatch.delenv("REPRO_ROW_BACKEND")
    monkeypatch.setattr(engine_mod, "_ROW_BACKEND_CACHE", {})
    backend, detail = choose_row_backend(128, np.int32, probe_batch=4, repeats=1)
    assert backend in ROW_BACKENDS
    assert "autotuned" in detail and "vmap" in detail and "pallas" in detail
    assert engine_mod._ROW_BACKEND_CACHE[(128, "int32", 4)] == (backend, detail)
    # the probe batch buckets to the serving batch (pow2, clamped): backend
    # ranking flips with batch size, so the probe must match the serve
    assert engine_mod._probe_batch_for(1) == 8
    assert engine_mod._probe_batch_for(24) == 32
    assert engine_mod._probe_batch_for(500) == 64
    # float keys: no 2-op candidate (the modular max identity is int-only)
    b2, d2 = choose_row_backend(128, np.float32, probe_batch=4, repeats=1)
    assert b2 in ("vmap", "pallas") and "pallas2op" not in d2


def test_sort_segments_pallas_backends(monkeypatch):
    # forcing each backend through the env knob must route sort_segments
    # through the fused kernel and stay oracle-exact, with the method
    # visible in last_report (what sortd's metrics surface per bucket)
    rng = np.random.default_rng(5)
    lens = [0, 1, 100, 513, 1000]
    arrs = [rng.integers(0, 1 << 30, n).astype(np.int32) for n in lens]
    flat = np.concatenate(arrs)
    for backend, method in (
        ("pallas", "bitonic_pallas"), ("pallas2op", "bitonic2op")
    ):
        monkeypatch.setenv("REPRO_ROW_BACKEND", backend)
        eng = SortEngine(TOPO)
        outs = eng.sort_segments(flat, lens)
        for a, o in zip(arrs, outs):
            np.testing.assert_array_equal(o, np.sort(a))
        assert eng.last_report["plan"].method == method
        assert f"row_backend={backend}" in eng.last_report["plan"].reason
        assert eng.last_report["overflow_retries"] == 0


def test_sort_segments_sentinel_tie_rows(monkeypatch):
    # dtype-max keys across every row backend: the valid prefix must keep
    # exactly seg_len sentinels per row (lost-element regression guard)
    hi = np.iinfo(np.int32).max
    rng = np.random.default_rng(9)
    arrs = [
        np.full(300, hi, np.int32),
        np.where(rng.random(777) < 0.5, hi, hi - 1).astype(np.int32),
    ]
    flat = np.concatenate(arrs)
    for backend in ("vmap", "pallas", "pallas2op"):
        monkeypatch.setenv("REPRO_ROW_BACKEND", backend)
        eng = SortEngine(TOPO)
        outs = eng.sort_segments(flat, [a.size for a in arrs])
        for a, o in zip(arrs, outs):
            np.testing.assert_array_equal(o, np.sort(a))


def test_sort_pairs_sentinel_ties_engine():
    # engine.sort_pairs pre-pads to the shape bucket before the traced fn;
    # the traced n_valid must keep pad zeros from displacing real payloads
    eng = SortEngine(TOPO)
    hi = np.iinfo(np.int32).max
    k = np.full(200, hi, np.int32)
    k[::3] = hi - 1
    v = np.arange(1, 201, dtype=np.int32)
    ks, vs = eng.sort_pairs(k, v)
    np.testing.assert_array_equal(np.asarray(ks), np.sort(k))
    np.testing.assert_array_equal(np.sort(np.asarray(vs)), v)


def test_estimate_batch_stats_worst_row_scaled():
    from repro.core import estimate_batch_stats, pack_segments

    # one constant (degenerate) row among uniform rows, all full length
    rows = [make_array("random", 1024, seed=s) for s in range(3)]
    rows.append(np.full(1024, 7, np.int32))
    lens = [r.size for r in rows]
    padded = pack_segments(np.concatenate(rows), lens, 1024)
    s = estimate_batch_stats(padded, lens, num_buckets=36)
    assert s.f_max_paper > 0.9  # the constant row dominates the reduction
    assert s.dup_top_frac > 0.9
    # the same pathological row at 1/16 the batch row length barely registers
    rows2 = rows[:3] + [np.full(64, 7, np.int32)]
    lens2 = [1024, 1024, 1024, 64]
    padded2 = pack_segments(np.concatenate(rows2), lens2, 1024)
    s2 = estimate_batch_stats(padded2, lens2, num_buckets=36)
    assert s2.f_max_paper < 0.2
    # zero-length rows are masked out entirely
    padded3 = pack_segments(rows[0], [1024, 0], 1024)
    s3 = estimate_batch_stats(padded3, [1024, 0], num_buckets=36)
    assert s3.dup_top_frac < 0.5


def test_pack_unpack_segments_roundtrip_and_errors():
    from repro.core import pack_segments, unpack_segments

    arrs = [np.arange(5, dtype=np.int32), np.zeros(0, np.int32),
            np.arange(8, dtype=np.int32)]
    lens = [a.size for a in arrs]
    packed = pack_segments(np.concatenate(arrs), lens, 8)
    assert packed.shape == (3, 8)
    for a, o in zip(arrs, unpack_segments(packed, lens)):
        np.testing.assert_array_equal(o, a)
    # left pad fill sorts to the end (dtype max default)
    assert packed[0, 5] == np.iinfo(np.int32).max
    # right alignment puts content at the row end (serving left-pad layout)
    right = pack_segments(np.concatenate(arrs), lens, 8, fill_value=0,
                          align="right")
    np.testing.assert_array_equal(right[0, 3:], arrs[0])
    assert right[0, 0] == 0
    with pytest.raises(ValueError, match="row_len"):
        pack_segments(np.arange(9, dtype=np.int32), [9], 8)
    with pytest.raises(ValueError, match="sum"):
        pack_segments(np.arange(9, dtype=np.int32), [4, 4], 8)
