"""shard_map distributed sort on 8 fake devices (subprocess: the main test
process must keep 1 device)."""

import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core import dist_sort, host_check_globally_sorted
from repro.data.distributions import make_array

from repro import compat

mesh = compat.make_mesh((8,), ("data",))
def exact(v, c, n8):
    vals = np.asarray(v).reshape(8, -1); cc = np.asarray(c).ravel()
    return np.concatenate([np.sort(vals[i])[:cc[i]] for i in range(8)])

for dist in ["random", "sorted", "reversed", "local"]:
    x = make_array(dist, 8192, seed=3)
    for method in ["sample", "paper"]:
        cf = 8.0  # sorted input sends a whole shard to one destination row
        v, c = dist_sort(jnp.asarray(x), mesh=mesh, axis_names=("data",),
                         method=method, capacity_factor=cf)
        got = exact(v, c, 8192)
        if method == "sample" or dist != "local":
            assert np.array_equal(got, np.sort(x)), (dist, method)
        else:
            # paper splitters under clustered values overflow capacity —
            # detectable as dropped elements, never silent corruption
            assert host_check_globally_sorted(np.asarray(v), np.asarray(c))

mesh2 = compat.make_mesh((2, 4), ("pod", "data"))
x = make_array("random", 8192, seed=5)
v, c = dist_sort(jnp.asarray(x), mesh=mesh2, axis_names=("pod", "data"),
                 method="hier", capacity_factor=8.0)
assert np.array_equal(exact(v, c, 8192), np.sort(x)), "hier"

# uint32 keys at full range: the hier stage-2 fill must stay typed (a bare
# python-int sentinel weak-types to int32 and overflows at trace time).
xu = make_array("random", 8192, seed=6, dtype=np.uint32)
v, c = dist_sort(jnp.asarray(xu), mesh=mesh2, axis_names=("pod", "data"),
                 method="hier", capacity_factor=8.0)
assert np.array_equal(exact(v, c, 8192), np.sort(xu)), "hier uint32"

# Valiant two-hop routing: sorted input at capacity_factor=2 — the direct
# route drops 3/4 of the data (send skew), valiant keeps all of it.
xs = make_array("sorted", 8192, seed=3)
v, c = dist_sort(jnp.asarray(xs), mesh=mesh, axis_names=("data",),
                 method="sample", capacity_factor=2.0)
assert int(np.asarray(c).sum()) < 8192, "expected direct-route overflow"
v, c = dist_sort(jnp.asarray(xs), mesh=mesh, axis_names=("data",),
                 method="valiant", capacity_factor=2.0)
assert np.array_equal(exact(v, c, 8192), np.sort(xs)), "valiant"
print("DIST_SORT_SUBPROCESS_OK")
"""


@pytest.mark.slow
def test_dist_sort_8_devices():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd="/root/repo",
    )
    assert "DIST_SORT_SUBPROCESS_OK" in r.stdout, r.stderr[-3000:]
