"""OHHC topology invariants vs the paper's Table 1.1 and link rules."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.topology import HHC_SIZE, OHHCTopology, hhc_cell_edges, table_1_1

EXPECTED_TABLE_1_1 = {
    (1, "full"): (6, 36),
    (2, "full"): (12, 144),
    (3, "full"): (24, 576),
    (4, "full"): (48, 2304),
    (1, "half"): (3, 18),
    (2, "half"): (6, 72),
    (3, "half"): (12, 288),
    (4, "half"): (24, 1152),
}


def test_table_1_1():
    assert table_1_1() == EXPECTED_TABLE_1_1


def test_hhc_cell_edges():
    edges = hhc_cell_edges()
    assert len(edges) == 9  # 2 triangles (3 each) + 3 cross
    # the cross pairing the §3.2(a) algorithm uses
    assert (0, 5) in edges and (1, 3) in edges and (2, 4) in edges


@pytest.mark.parametrize("d_h", [1, 2, 3, 4])
@pytest.mark.parametrize("variant", ["full", "half"])
def test_degrees_and_optical(d_h, variant):
    t = OHHCTopology(d_h, variant)
    # uniform HHC degree: 3 intra-cell neighbours + d_h−1 hypercube links
    for local in range(t.procs_per_group):
        nbrs = t.electrical_neighbors(local)
        assert len(nbrs) == 3 + (d_h - 1), (local, nbrs)
        assert local not in nbrs
    # optical transpose symmetry: (g,x)→(x,g)→(g,x)
    for g in range(t.num_groups):
        for x in range(t.procs_per_group):
            p = t.optical_partner(g, x)
            if p is not None:
                g2, x2 = p
                assert t.optical_partner(g2, x2) == (g, x)


@pytest.mark.parametrize("d_h", [1, 2, 3])
@pytest.mark.parametrize("variant", ["full", "half"])
def test_optical_links_are_an_involution(d_h, variant):
    """Regression for the `optical_partner` guard collapse: every node has
    ≤ 1 optical link, the link set is an involution with no fixed points
    (the (g,g) self-transpose hole carries no link), and the undirected
    edge set matches the G·(G−1)/2 closed form."""
    t = OHHCTopology(d_h, variant)
    edges = set()
    for g in range(t.num_groups):
        for x in range(t.procs_per_group):
            p = t.optical_partner(g, x)
            if x == g or x >= t.num_groups:
                assert p is None  # hole / no transpose image
                continue
            assert p is not None and p != (g, x)  # no fixed points
            assert t.optical_partner(*p) == (g, x)  # involution
            a, b = t.global_id(g, x), t.global_id(*p)
            edges.add((min(a, b), max(a, b)))
    assert len(edges) == t.optical_edge_count_closed_form()
    # ≤1 optical link per node: each gid appears in at most one edge
    seen = [gid for e in edges for gid in e]
    assert len(seen) == len(set(seen))
    # (summary edge counts vs the closed forms are property-tested over the
    # full d_h grid in tests/test_netsim.py::test_edge_counts_and_degrees_bounded)


@given(d_h=st.integers(1, 5), variant=st.sampled_from(["full", "half"]))
@settings(max_examples=20, deadline=None)
def test_sizes_property(d_h, variant):
    t = OHHCTopology(d_h, variant)
    assert t.procs_per_group == HHC_SIZE * 2 ** (d_h - 1)
    assert t.total_procs == t.num_groups * t.procs_per_group
    if variant == "full":
        assert t.num_groups == t.procs_per_group
    else:
        assert 2 * t.num_groups == t.procs_per_group
    # addressing is a bijection
    for gid in [0, t.total_procs - 1, t.total_procs // 2]:
        g, l = t.addr(gid)
        assert t.global_id(g, l) == gid
