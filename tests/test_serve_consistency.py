"""Serving correctness: prefill+decode logits ≡ full forward logits, for
every cache flavour (GQA / window / MoE / MLA expanded+absorbed / SSM /
hybrid / encdec / M-RoPE)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import MLAConfig
from repro.models.common import NO_SHARD

ARCHS = list(registry.ARCHS)


def _mk(cfg, B, S, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["enc_frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq_len, cfg.d_model), jnp.float32
        )
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model), jnp.float32
        )
        batch["positions_thw"] = jnp.broadcast_to(
            jnp.arange(S), (3, B, S)
        ).astype(jnp.int32)
    return batch


def _slice(batch, cfg, upto):
    out = dict(batch)
    out["tokens"] = batch["tokens"][:, :upto]
    if "positions_thw" in batch:
        out["positions_thw"] = batch["positions_thw"][:, :, :upto]
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = registry.get_config(arch, smoke=True).replace(
        dtype=jnp.float32, remat=False
    )
    api = registry.get_model_api(cfg)
    B, S = 2, 24
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = _mk(cfg, B, S, jax.random.PRNGKey(1))
    logits, _ = api.forward(params, batch, cfg, NO_SHARD)
    cache = api.init_cache(cfg, B, S + 4)
    last, cache = api.prefill(params, _slice(batch, cfg, S - 2), cfg, NO_SHARD, cache)
    errs = [float(np.max(np.abs(np.asarray(last) - np.asarray(logits[:, S - 3]))))]
    for i, pos in enumerate((S - 2, S - 1)):
        lg, cache = api.decode_step(
            params, batch["tokens"][:, pos : pos + 1], cfg, NO_SHARD, cache, pos
        )
        errs.append(float(np.max(np.abs(np.asarray(lg) - np.asarray(logits[:, pos])))))
    assert max(errs) < 2e-2, (arch, errs)


def test_mla_absorbed_equals_expanded():
    cfg = registry.get_config("deepseek-v2-lite-16b", smoke=True).replace(
        dtype=jnp.float32, remat=False
    )
    api = registry.get_model_api(cfg)
    B, S = 2, 16
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    outs = {}
    for absorb in (False, True):
        c = cfg.replace(mla=MLAConfig(
            kv_lora_rank=cfg.mla.kv_lora_rank,
            qk_nope_head_dim=cfg.mla.qk_nope_head_dim,
            qk_rope_head_dim=cfg.mla.qk_rope_head_dim,
            v_head_dim=cfg.mla.v_head_dim,
            absorb=absorb,
        ))
        cache = api.init_cache(c, B, S + 2)
        _, cache = api.prefill(params, {"tokens": toks[:, :-1]}, c, NO_SHARD, cache)
        lg, _ = api.decode_step(params, toks[:, -1:], c, NO_SHARD, cache, S - 1)
        outs[absorb] = np.asarray(lg)
    np.testing.assert_allclose(outs[False], outs[True], atol=1e-3)


def test_serving_engine_end_to_end():
    from repro.serve.engine import Request, ServeEngine

    cfg = registry.get_config("gemma3-4b", smoke=True)
    api = registry.get_model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, api, max_len=96)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, ln).astype(np.int32), 8)
            for i, ln in enumerate([5, 17, 3, 11])]
    out = eng.generate(reqs)
    assert set(out) == {0, 1, 2, 3}
    assert all(len(v) == 8 for v in out.values())
    # regression: empty batch returns empty result, not max()-of-empty
    assert eng.generate([]) == {}
