"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import repro.kernels.ops as ops
from repro.kernels import ref


@pytest.mark.parametrize("n", [1, 2, 100, 127, 128, 129, 512, 1000, 4096, 10000])
@pytest.mark.parametrize("dtype", [np.int32, np.float32, np.int16])
def test_local_sort_sweep(n, dtype, rng):
    if dtype == np.int16:
        x = rng.integers(-(2**14), 2**14, n).astype(dtype)
    elif np.issubdtype(dtype, np.integer):
        x = rng.integers(-(2**30), 2**30, n).astype(dtype)
    else:
        x = rng.normal(size=n).astype(dtype)
    out = ops.local_sort(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(out), np.sort(x))


@given(n=st.integers(1, 3000), seed=st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_local_sort_property(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 50, n).astype(np.int32)  # duplicate-heavy
    out = ops.local_sort(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(out), np.sort(x))


def test_multi_tile_merge(monkeypatch, rng):
    monkeypatch.setattr(ops, "MAX_TILE", 512)
    x = rng.normal(size=4000).astype(np.float32)
    out = ops.local_sort(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(out), np.sort(x))


@pytest.mark.parametrize("n", [10, 128, 1000, 5000])
def test_sort_pairs(n, rng):
    k = rng.integers(0, 64, n).astype(np.int32)
    v = np.arange(n, dtype=np.int32)
    ks, vs = ops.local_sort_pairs(jnp.asarray(k), jnp.asarray(v))
    rk, _ = ref.ref_sort_pairs(jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rk))
    # payload permutation is key-consistent (bitonic is unstable: compare
    # the value multiset inside each key group)
    ks_np, vs_np = np.asarray(ks), np.asarray(vs)
    for key in np.unique(k):
        np.testing.assert_array_equal(
            np.sort(vs_np[ks_np == key]), np.sort(v[k == key])
        )


@pytest.mark.parametrize("n", [10, 100, 129, 1000])
@pytest.mark.parametrize("dtype", [np.int32, np.uint32, np.int16])
def test_sort_pairs_sentinel_ties(n, dtype, rng):
    # Regression: keys equal to the dtype-max pad sentinel must not lose
    # their payloads to the zero-padded tail when n < bucketed_length(n).
    hi = np.iinfo(dtype).max
    k = np.full(n, hi, dtype=dtype)
    k[rng.random(n) < 0.5] = hi - 1  # mix of max and near-max keys
    v = np.arange(1, n + 1, dtype=np.int32)  # payloads, none zero
    ks, vs = ops.local_sort_pairs(jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_array_equal(np.asarray(ks), np.sort(k))
    # every real payload survives — the pad's zero payloads must not appear
    np.testing.assert_array_equal(np.sort(np.asarray(vs)), v)


def test_multi_tile_merge_minimal_passes(monkeypatch, rng):
    # Block odd-even transposition needs exactly num_tiles alternating
    # half-passes; adversarial reverse-sorted input makes every element
    # travel the full distance, so any fewer passes would fail.
    monkeypatch.setattr(ops, "MAX_TILE", 512)
    for n in (1536, 2560, 4000):  # 3, 5, 8 tiles — odd counts included
        x = np.arange(n, 0, -1).astype(np.int32)
        out = ops.local_sort(jnp.asarray(x))
        np.testing.assert_array_equal(np.asarray(out), np.sort(x))


def test_bucket_count_rank_empty():
    c, r = ops.bucket_count_rank(jnp.asarray(np.zeros(0, np.int32)), 4)
    assert c.shape == (4,) and r.shape == (0,)
    np.testing.assert_array_equal(np.asarray(c), np.zeros(4, np.int32))


def test_bucket_count_rank_out_of_range():
    bad = jnp.asarray(np.array([0, 5, 1], np.int32))  # 5 ∉ [0, 4)
    with pytest.raises(ValueError, match="out of range"):
        ops.bucket_count_rank(bad, 4, debug=True)
    low = jnp.asarray(np.array([0, -1, 1], np.int32))
    with pytest.raises(ValueError, match="out of range"):
        ops.bucket_count_rank(low, 4, debug=True)


@pytest.mark.parametrize("n,buckets,tile", [(100, 4, 32), (3000, 16, 1024), (257, 3, 64)])
def test_bucket_count_rank(n, buckets, tile, rng):
    ids = rng.integers(0, buckets, n).astype(np.int32)
    c, r = ops.bucket_count_rank(jnp.asarray(ids), buckets, tile=tile)
    rc, rr = ref.ref_bucket_count_rank(jnp.asarray(ids), buckets)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(rr))


def test_merge_tiles(rng):
    from repro.kernels import bitonic

    a = np.sort(rng.normal(size=256).astype(np.float32))
    b = np.sort(rng.normal(size=256).astype(np.float32))
    lo, hi = bitonic.merge_tiles(jnp.asarray(a), jnp.asarray(b), interpret=True)
    m = np.sort(np.concatenate([a, b]))
    np.testing.assert_allclose(np.asarray(lo), m[:256])
    np.testing.assert_allclose(np.asarray(hi), m[256:])
