"""GPipe pipeline parallelism: numerics vs sequential execution (subprocess
with 4 fake devices on a 'pipe' axis)."""

import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.runtime.pipeline import pipeline_forward, bubble_fraction

mesh = compat.make_mesh((4,), ("pipe",))
L, M, mb, d = 8, 6, 2, 16
key = jax.random.PRNGKey(0)
W = jax.random.normal(key, (L, d, d)) * 0.3
params = {"w": W}
def block(p, x):
    return jnp.tanh(x @ p["w"])
x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))

# sequential reference
ref = x
for l in range(L):
    ref = jnp.tanh(ref @ W[l])

out = pipeline_forward(params, x, block, mesh=mesh, pipe_axis="pipe")
err = float(jnp.max(jnp.abs(out - ref)))
print("pipeline vs sequential:", err)
assert err < 1e-5, err
assert abs(bubble_fraction(4, 6) - 3/9) < 1e-9
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_pipeline_matches_sequential():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd="/root/repo",
    )
    assert "PIPELINE_OK" in r.stdout, (r.stdout[-1500:], r.stderr[-2500:])
