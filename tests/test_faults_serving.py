"""Fault-tolerant degraded serving, end to end (DESIGN.md §11).

Three layers, one vocabulary:

* ``net.faults`` — ``rebuild_degraded`` must be all-or-nothing: a fault
  set that strands any live sender raises a typed ``GatherImpossible``
  with the full cut-off node set, never a partial schedule (and the
  property test pins that every *rebuilt* schedule is acyclic, covers
  every node, and replays with zero simulator reroutes);
* ``core.engine`` — the fallback ladder: degraded-but-possible scenarios
  re-price the plan (annotated predicted slowdown), impossible ones fall
  back to the healthy host path; switching scenarios never recompiles
  and never serves a stale healthy-topology price;
* ``serve`` — a ``Sortd`` in degraded mode stays exact and reports it;
  ``SortdFleet.apply_fault_scenario`` maps ``worker_down`` onto the SAME
  live-failover path ``ChaosConfig`` kills take, with byte-identical
  results and matching failover counters.
"""

from __future__ import annotations

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.engine import SortEngine, SortPlan
from repro.core.schedule import AccumulationSchedule
from repro.core.topology import OHHCTopology
from repro.net.faults import (
    FaultScenario,
    GatherImpossible,
    degraded_gather_rounds,
    predicted_slowdown,
    rebuild_degraded,
)
from repro.net.sim import simulate_schedule


# --------------------------------------------------------- rebuild_degraded


def test_group_uplinks_down_raises_with_the_full_node_set():
    """All uplinks of one group dead: the group is optically islanded and
    the refusal must carry the WHOLE stranded group, not a one-send
    message (the all-or-nothing regression this suite pins)."""
    topo = OHHCTopology(1, "full")
    sc = FaultScenario.group_uplinks_down(topo, 1)
    with pytest.raises(GatherImpossible) as ei:
        rebuild_degraded(AccumulationSchedule.build(topo), topo, sc.router(topo))
    group1 = {topo.global_id(1, l) for l in range(topo.procs_per_group)}
    assert ei.value.nodes == frozenset(group1)
    assert "cannot be rerouted" in str(ei.value)


def test_group_uplinks_down_half_variant():
    topo = OHHCTopology(1, "half")
    sc = FaultScenario.group_uplinks_down(topo, 1)
    with pytest.raises(GatherImpossible) as ei:
        degraded_gather_rounds(topo, sc)
    assert ei.value.nodes == frozenset(
        topo.global_id(1, l) for l in range(topo.procs_per_group)
    )


def test_worker_down_nodes_carries_the_dead_hub():
    topo = OHHCTopology(1, "full")
    with pytest.raises(GatherImpossible) as ei:
        degraded_gather_rounds(topo, FaultScenario.worker_down(1))
    assert ei.value.nodes == frozenset({topo.global_id(1, 0)})


def _round_graph_is_acyclic(rnd, topo) -> bool:
    """DFS cycle check over one round's directed send graph: a cycle
    within a round would deadlock its store-and-forward execution."""
    adj: dict[int, list[int]] = {}
    for s in rnd:
        adj.setdefault(topo.global_id(*s.src), []).append(
            topo.global_id(*s.dst)
        )
    state: dict[int, int] = {}  # 1 = on stack, 2 = done

    def dfs(u: int) -> bool:
        state[u] = 1
        for v in adj.get(u, ()):
            if state.get(v) == 1:
                return False
            if state.get(v) is None and not dfs(v):
                return False
        state[u] = 2
        return True

    return all(state.get(u) == 2 or dfs(u) for u in list(adj))


@given(k=st.integers(0, 12), seed=st.integers(0, 31))
@settings(max_examples=30, deadline=None)
def test_random_klink_scenarios_rebuild_or_refuse(k, seed):
    """Satellite property: over random k-link fault draws the rebuild is
    either a typed refusal (nonempty stranded node set) or a schedule
    that is acyclic per round, bounded, covers every node's payload, and
    replays on the faulted graph with ZERO simulator-level reroutes."""
    topo = OHHCTopology(1, "full")
    sc = FaultScenario.random_links(topo, k, seed=seed)
    router = sc.router(topo)
    healthy_rounds = AccumulationSchedule.build(topo).rounds
    try:
        rounds = rebuild_degraded(healthy_rounds, topo, router)
    except GatherImpossible as e:
        assert e.nodes, "refusal must name the stranded nodes"
        assert all(0 <= g < topo.total_procs for g in e.nodes)
        return
    # bounded: every dead direct link adds at most diameter relay hops
    assert len(rounds) <= len(healthy_rounds) * (router.diameter() + 1)
    for rnd in rounds:
        assert _round_graph_is_acyclic(rnd, topo)
        for s in rnd:
            src, dst = topo.global_id(*s.src), topo.global_id(*s.dst)
            assert src == dst or router.link_kind(src, dst) is not None, (
                f"rebuilt send {s.src}->{s.dst} uses a dead/absent link"
            )
    # cover all nodes: every non-master node's chunk departs somewhere
    # (relay chains may add more senders, e.g. the master forwarding)
    senders = {topo.global_id(*s.src) for rnd in rounds for s in rnd}
    assert set(range(1, topo.total_procs)) <= senders
    res = simulate_schedule(rounds, topo, router=router, chunk_sizes=1)
    assert res.rerouted_messages == 0
    assert res.master_elems == topo.total_procs


# ----------------------------------------------------- engine fallback ladder


def _x(n=4096, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << 31, size=n).astype(np.int32)


def test_engine_degraded_plan_is_annotated_and_exact():
    eng = SortEngine(OHHCTopology(1, "full"))
    eng.set_fault_scenario(FaultScenario.optical_link_down(1))
    x = _x()
    out = eng.sort(x)
    np.testing.assert_array_equal(out, np.sort(x))
    plan = eng.last_report["plan"]
    assert plan.fault == "optical_g1_down"
    assert plan.fault_slowdown is not None and plan.fault_slowdown > 1.0
    assert "predicted" in plan.reason and "gather slowdown" in plan.reason
    # the quoted slowdown IS the netsim barrier-mode ratio, not a guess
    chunk = -(-x.size // eng.topo.total_procs)  # n=4096 is its own pow2 bucket
    _, _, ratio = predicted_slowdown(
        eng.topo, eng.fault_scenario, chunk_sizes=chunk
    )
    assert plan.fault_slowdown == pytest.approx(ratio, rel=1e-6)


def test_engine_impossible_scenario_falls_back_to_host():
    topo = OHHCTopology(1, "full")
    eng = SortEngine(topo)
    eng.set_fault_scenario(FaultScenario.group_uplinks_down(topo, 1))
    x = _x(seed=1)
    # forced sim plan: degraded serving must OVERRIDE the force, not error
    forced = SortPlan("sim", "paper", 512, 4096, "test force")
    out = eng.sort(x, plan=forced)
    np.testing.assert_array_equal(out, np.sort(x))
    plan = eng.last_report["plan"]
    assert plan.path == "host" and plan.fault == "uplinks_g1_down"
    assert "impossible" in plan.reason and "host" in plan.reason
    assert plan.fault_slowdown is None


def test_engine_empty_scenario_is_a_noop():
    eng = SortEngine(OHHCTopology(1, "full"))
    eng.set_fault_scenario(FaultScenario())  # named but removes nothing
    x = _x(seed=2)
    np.testing.assert_array_equal(eng.sort(x), np.sort(x))
    assert eng.last_report["plan"].fault is None


def test_sort_segments_impossible_scenario_host_fallback():
    topo = OHHCTopology(1, "full")
    eng = SortEngine(topo)
    eng.set_fault_scenario(FaultScenario.worker_down(1))
    rng = np.random.default_rng(3)
    lens = [0, 1, 17, 100, 64]
    segs = [rng.integers(0, 1 << 30, n).astype(np.int32) for n in lens]
    flat = np.concatenate(segs)
    outs = eng.sort_segments(flat, lens)
    for seg, out in zip(segs, outs):
        np.testing.assert_array_equal(out, np.sort(seg))
    plan = eng.last_report["plan"]
    assert plan.path == "host" and plan.fault == "worker1_down"
    with pytest.raises(ValueError):
        eng.sort_segments(flat, lens, return_padded=True)


def test_sort_segments_possible_scenario_annotates_plan():
    eng = SortEngine(OHHCTopology(1, "full"))
    eng.set_fault_scenario(FaultScenario.optical_link_down(2))
    rng = np.random.default_rng(4)
    lens = [9, 33, 100]
    segs = [rng.integers(0, 1 << 30, n).astype(np.int32) for n in lens]
    outs = eng.sort_segments(np.concatenate(segs), lens)
    for seg, out in zip(segs, outs):
        np.testing.assert_array_equal(out, np.sort(seg))
    plan = eng.last_report["plan"]
    assert plan.path == "sim" and plan.fault == "optical_g2_down"


# -------------------------------------------------- satellite 3: plan caches


def test_scenario_switching_reprices_without_recompiling():
    """A flapping fault scenario must (a) never serve the healthy comm
    price for a degraded plan — distinct cache keys per scenario — and
    (b) never re-trace the jit executable (the sorted bytes are
    fault-independent)."""
    eng = SortEngine(OHHCTopology(1, "full"))  # n < host_threshold → sim path
    x = _x(seed=5)
    sc = FaultScenario.optical_link_down(1)

    eng.sort(x)
    assert eng.last_report["plan"].path == "sim"  # the jit path, so
    # trace_count below actually guards against fault-driven recompiles
    healthy_reason = eng.last_report["plan"].reason
    healthy_price = eng.comm_cost_estimate(x.size)
    traces_after_warm = eng.trace_count

    eng.set_fault_scenario(sc)
    eng.sort(x)
    degraded_reason = eng.last_report["plan"].reason
    degraded_price = eng.comm_cost_estimate(x.size)
    assert degraded_reason != healthy_reason
    assert degraded_price > healthy_price  # not a stale healthy price
    # both prices live side by side under distinct scenario-name keys
    names = {key[3] for key in eng._comm_sim_cache}
    assert {None, sc.name} <= names

    eng.set_fault_scenario(None)
    eng.sort(x)
    assert eng.last_report["plan"].reason == healthy_reason
    assert eng.comm_cost_estimate(x.size) == healthy_price

    eng.set_fault_scenario(sc)
    eng.sort(x)
    assert eng.last_report["plan"].reason == degraded_reason
    # flapping scenarios never re-trace: the jit cache is shared
    assert eng.trace_count == traces_after_warm
    # repeat of the same scenario reuses the classification, too
    assert list(eng._fault_info) == [sc.name]


# ------------------------------------------------------------ sortd serving


def test_sortd_degraded_serving_is_exact_and_reported():
    from repro.serve.sortd import Sortd, SortdConfig

    eng = SortEngine(OHHCTopology(1, "full"))
    xs = [_x(2048, seed=s) for s in range(4)]
    with Sortd(eng, SortdConfig(max_batch=4, max_wait_s=0.005)) as sd:
        for x in xs[:2]:
            np.testing.assert_array_equal(
                sd.submit(x).result(timeout=120), np.sort(x)
            )
        m0 = sd.metrics()
        assert m0["fault_scenario"] is None
        sd.set_fault_scenario(FaultScenario.optical_link_down(1))
        for x in xs[2:]:
            np.testing.assert_array_equal(
                sd.submit(x).result(timeout=120), np.sort(x)
            )
        m1 = sd.metrics()
        assert m1["fault_scenario"] == "optical_g1_down"
        assert m1["degraded_flushes"] > m0["degraded_flushes"]
        sd.set_fault_scenario(None)
        assert sd.metrics()["fault_scenario"] is None


# ------------------------------------- satellite 2: fleet failover equivalence


def _keyed_input(pred, workers: int, count: int, seed: int, avoid=None):
    """Arrays sharing one affinity key whose rendezvous worker satisfies
    ``pred`` (same (dtype, pow2 bucket) key ⇒ same bin ⇒ same worker).
    Searches dtype × pow2-size so every worker index is reachable."""
    from repro.serve.fleet import rendezvous_worker
    from repro.serve.sortd import affinity_key

    live = tuple(range(workers))
    for dt in (np.int32, np.int64, np.uint32):
        for exp in range(6, 14):  # 64 .. 8192, all under max_bucket
            n = 1 << exp
            key = affinity_key(np.zeros(n, dt))
            if key == avoid:
                continue
            if pred(rendezvous_worker(key, live)):
                rng = np.random.default_rng(seed)
                return key, [
                    rng.integers(0, 1 << 30, n).astype(dt)
                    for _ in range(count)
                ]
    raise AssertionError("no (dtype, size) key found for the predicate")


def _fleet_cfg(backlog: int):
    from repro.serve.fleet import FleetConfig
    from repro.serve.sortd import SortdConfig

    return FleetConfig(
        workers=3,
        # no stealing: the victim must HOLD its binned backlog
        steal_watermark=10_000,
        heartbeat_interval_s=0.005,
        heartbeat_timeout_s=10.0,  # cold compiles must not fail over bystanders
        worker_config=SortdConfig(
            max_queue=256,
            max_batch=backlog + 8,  # never flush on batch size
            max_wait_s=1.0,  # hold the bin long enough for the kill to land
            block_on_full=False,
        ),
    )


@pytest.mark.parametrize(
    ("victim", "backlog"), [(0, 6), (1, 6), (1, 12)]
)
def test_chaos_kill_and_worker_down_are_the_same_failover(victim, backlog):
    """Chaos-killing worker ``w`` and applying ``worker_down(w)`` must be
    indistinguishable: byte-identical results and identical failover /
    re-admission counters (they are literally one code path)."""
    from repro.serve.fleet import ChaosConfig, SortdFleet

    vkey, xs = _keyed_input(lambda w: w == victim, 3, backlog, seed=13)
    # the trigger/extra request routes to a survivor, not the victim
    _, (extra,) = _keyed_input(
        lambda w: w != victim, 3, 1, seed=14, avoid=vkey
    )
    warm = xs[0]

    def run(chaos, apply_scenario):
        cfg = _fleet_cfg(backlog)
        with SortdFleet(cfg, chaos=chaos) as fleet:
            # warm the victim's bucket so the backlog phase is compile-free
            fleet.submit(warm).result(timeout=120)
            futs = [fleet.submit(x) for x in xs]
            fut_extra = fleet.submit(extra)  # in chaos mode: the trigger
            if apply_scenario:
                fleet.apply_fault_scenario(FaultScenario.worker_down(victim))
            outs = [f.result(timeout=120) for f in futs]
            out_extra = fut_extra.result(timeout=120)
            deadline_metrics = fleet.metrics()
            return outs, out_extra, deadline_metrics

    # run A: deterministic chaos kill on the (warm + backlog + 1)-th admission
    chaos = ChaosConfig(
        name="kill-victim",
        kill_worker_after=1 + backlog + 1,
        kill_worker=victim,
    )
    outs_a, extra_a, m_a = run(chaos, apply_scenario=False)
    # run B: the same kill expressed as a simulated topology fault
    outs_b, extra_b, m_b = run(None, apply_scenario=True)

    for x, oa, ob in zip(xs, outs_a, outs_b):
        np.testing.assert_array_equal(oa, np.sort(x))
        assert oa.tobytes() == ob.tobytes()
    np.testing.assert_array_equal(extra_a, np.sort(extra))
    assert extra_a.tobytes() == extra_b.tobytes()

    fa, fb = m_a["fleet"], m_b["fleet"]
    assert fa["failovers"] == fb["failovers"] == 1
    assert fa["readmitted"] == fb["readmitted"] == backlog
    assert m_a["workers"][str(victim)]["state"] == "dead"
    assert m_b["workers"][str(victim)]["state"] == "dead"
    # the fleet records the shared scenario vocabulary in both modes
    assert fa["fault_scenario"] == fb["fault_scenario"] == f"worker{victim}_down"


def test_fleet_residual_link_fault_degrades_survivors():
    """A pure link fault kills nobody: every worker's engine serves the
    degraded scenario (exact results, annotated plans), and clearing it
    heals the fleet."""
    from repro.serve.fleet import FleetConfig, SortdFleet

    rng = np.random.default_rng(21)
    xs = [rng.integers(0, 1 << 30, 1024).astype(np.int32) for _ in range(8)]
    cfg = FleetConfig(workers=2, heartbeat_timeout_s=10.0)
    with SortdFleet(cfg) as fleet:
        for x in xs:  # warm both workers before faulting
            fleet.submit(x).result(timeout=120)
        summary = fleet.apply_fault_scenario(FaultScenario.optical_link_down(1))
        assert summary == {
            "scenario": "optical_g1_down",
            "killed_workers": [],
            "residual_faults": 1,
        }
        for x in xs:
            np.testing.assert_array_equal(
                fleet.submit(x).result(timeout=120), np.sort(x)
            )
        m = fleet.metrics()
        assert m["fleet"]["fault_scenario"] == "optical_g1_down"
        assert all(
            w["fault"] == "optical_g1_down" for w in m["workers"].values()
        )
        assert fleet.report()["faults"] == summary
        fleet.apply_fault_scenario(None)
        m = fleet.metrics()
        assert m["fleet"]["fault_scenario"] is None
        assert all(w["fault"] is None for w in m["workers"].values())
        assert m["fleet"]["failovers"] == 0
