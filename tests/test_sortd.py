"""sortd micro-batching service (DESIGN.md §8): coalescing, deadlines,
backpressure, oversize fallback, metrics accounting — plus the ServeEngine
empty-batch regression that motivated the serving guard."""

import threading
import time

import numpy as np
import pytest

from repro.core import OHHCTopology, SortEngine
from repro.data.distributions import make_array
from repro.serve.sortd import QueueFull, Sortd, SortdConfig

TOPO = OHHCTopology(1, "full")


def mk(n, seed=0, dtype=np.int32, dist="random"):
    return make_array(dist, n, seed=seed, dtype=np.dtype(dtype))


# ------------------------------------------------------------- basic flow
def test_submit_result_matches_oracle():
    with Sortd(SortEngine(TOPO)) as sd:
        xs = [mk(n, seed=n) for n in (5, 130, 1000, 2049)]
        futs = [sd.submit(x) for x in xs]
        for x, f in zip(xs, futs):
            np.testing.assert_array_equal(f.result(timeout=120), np.sort(x))
        m = sd.metrics()
    assert m["completed"] == len(xs)
    assert m["failed"] == 0


def test_sync_sort_convenience():
    with Sortd(SortEngine(TOPO)) as sd:
        x = mk(777, seed=3)
        np.testing.assert_array_equal(sd.sort(x), np.sort(x))


def test_flush_on_deadline_single_request():
    """A lone request must not wait for max_batch: the deadline flushes a
    batch of one within max_wait_s (plus sort time)."""
    cfg = SortdConfig(max_batch=64, max_wait_s=0.02)
    with Sortd(SortEngine(TOPO), cfg) as sd:
        x = mk(512, seed=1)
        t0 = time.monotonic()
        out = sd.submit(x).result(timeout=120)
        elapsed = time.monotonic() - t0
        np.testing.assert_array_equal(out, np.sort(x))
        m = sd.metrics()
    assert m["flushes"]["deadline"] >= 1
    assert m["flushes"]["full"] == 0
    bucket = m["buckets"]["int32/512"]
    assert bucket["requests"] == 1 and bucket["mean_batch"] == 1.0
    # generous bound: deadline + one warm-ish sort, not an unbounded wait
    assert elapsed < 60.0


def test_flush_on_full_batch():
    cfg = SortdConfig(max_batch=4, max_wait_s=30.0)  # deadline can't be the trigger
    with Sortd(SortEngine(TOPO), cfg, start=False) as sd:
        xs = [mk(300, seed=s) for s in range(4)]
        futs = [sd.submit(x) for x in xs]
        sd.start()
        for x, f in zip(xs, futs):
            np.testing.assert_array_equal(f.result(timeout=120), np.sort(x))
        m = sd.metrics()
    assert m["flushes"]["full"] == 1
    assert m["buckets"]["int32/512"]["mean_batch"] == 4.0


def test_oversize_falls_back_to_direct_engine_path():
    cfg = SortdConfig(max_bucket=256, max_wait_s=0.005)
    eng = SortEngine(TOPO)
    with Sortd(eng, cfg) as sd:
        x = mk(1000, seed=7)  # > max_bucket → never coalesced
        out = sd.submit(x).result(timeout=120)
        np.testing.assert_array_equal(out, np.sort(x))
        m = sd.metrics()
    assert m["oversize_direct"] == 1
    assert "int32/direct" in m["buckets"]
    assert m["buckets"]["int32/direct"]["pad_waste"] == 0.0
    # nothing else in that bucket namespace: no padded bin was created
    assert not any(k.startswith("int32/1024") for k in m["buckets"])


def test_mixed_dtype_requests_never_coalesce():
    """Same lengths, different dtypes → separate bins, separate batches."""
    cfg = SortdConfig(max_batch=64, max_wait_s=0.01)
    with Sortd(SortEngine(TOPO), cfg, start=False) as sd:
        xi = [mk(200, seed=s, dtype=np.int32) for s in range(3)]
        xf = [mk(200, seed=s, dtype=np.float32) for s in range(3)]
        futs = [sd.submit(x) for x in xi + xf]
        sd.start()
        for x, f in zip(xi + xf, futs):
            out = f.result(timeout=120)
            assert out.dtype == x.dtype
            np.testing.assert_array_equal(out, np.sort(x))
        m = sd.metrics()
    assert set(m["buckets"]) == {"int32/256", "float32/256"}
    for b in m["buckets"].values():
        assert b["requests"] == 3 and b["batches"] == 1 and b["mean_batch"] == 3.0


def test_queue_full_backpressure():
    cfg = SortdConfig(max_queue=2, block_on_full=False)
    sd = Sortd(SortEngine(TOPO), cfg, start=False)  # stalled worker: queue fills
    try:
        f1 = sd.submit(mk(100, seed=1))
        f2 = sd.submit(mk(100, seed=2))
        with pytest.raises(QueueFull):
            sd.submit(mk(100, seed=3))
        assert sd.metrics()["rejected"] == 1
        sd.start()  # backlog drains once the worker runs
        for f, seed in ((f1, 1), (f2, 2)):
            np.testing.assert_array_equal(
                f.result(timeout=120), np.sort(mk(100, seed=seed))
            )
    finally:
        sd.close()
    assert sd.metrics()["completed"] == 2


def test_close_flushes_pending_and_rejects_new():
    cfg = SortdConfig(max_batch=64, max_wait_s=30.0)  # nothing flushes on its own
    sd = Sortd(SortEngine(TOPO), cfg, start=False)
    x = mk(128, seed=9)
    fut = sd.submit(x)
    sd.close()  # never-started service must still serve its backlog
    np.testing.assert_array_equal(fut.result(timeout=120), np.sort(x))
    assert sd.metrics()["flushes"]["close"] >= 1
    with pytest.raises(RuntimeError):
        sd.submit(x)


def test_close_under_queued_backlog_drains_every_future():
    """Regression for the fleet's drain lean: close() called while a real
    backlog is still queued/binned on a LIVE worker must serve all of it —
    every pre-close Future resolves exactly — before returning."""
    cfg = SortdConfig(max_batch=1024, max_wait_s=30.0)  # only close flushes
    xs = [mk(n, seed=n) for n in (70, 300, 300, 1200, 1200, 1200, 2900)]
    with Sortd(SortEngine(TOPO), cfg) as sd:
        futs = [sd.submit(x) for x in xs]
        # no deadline can expire and no batch fills: the backlog is real
    for x, f in zip(xs, futs):
        np.testing.assert_array_equal(f.result(timeout=0), np.sort(x))
    m = sd.metrics()
    assert m["completed"] == len(xs) and m["failed"] == 0
    assert m["flushes"]["close"] >= 1
    assert m["flushes"]["deadline"] == 0 and m["flushes"]["full"] == 0


def test_idle_flush_beats_the_coalescing_deadline():
    """With ``idle_flush_s`` set, a lone request (empty queue ⇒ nobody to
    coalesce with) flushes on the short idle budget instead of waiting out
    ``max_wait_s`` — the fleet's throughput lever (DESIGN.md §10)."""
    cfg = SortdConfig(max_wait_s=2.0, idle_flush_s=1e-4)
    with Sortd(SortEngine(TOPO), cfg) as sd:
        x = mk(512, seed=2)
        sd.sort(x)  # warm the bucket executable
        t0 = time.monotonic()
        out = sd.submit(x).result(timeout=120)
        elapsed = time.monotonic() - t0
        m = sd.metrics()
    np.testing.assert_array_equal(out, np.sort(x))
    assert m["flushes"]["idle"] >= 1
    assert elapsed < 1.0  # far below the 2s deadline it did NOT wait out


def test_kill_crashes_worker_without_draining():
    """Chaos contract: kill() aborts the worker at its next tick; queued
    futures dangle (the FLEET re-admits them, a lone sortd never will)."""
    from repro.serve.sortd import WorkerKilled  # noqa: F401 — exported name

    cfg = SortdConfig(max_batch=1024, max_wait_s=30.0)
    with Sortd(SortEngine(TOPO), cfg) as sd:
        fut = sd.submit(mk(256, seed=4))
        sd.kill()
        deadline = time.monotonic() + 10.0
        while sd.worker_alive and time.monotonic() < deadline:
            time.sleep(0.002)
        assert not sd.worker_alive
        assert not fut.done()  # intentionally dangling — a real crash
    assert not fut.done()  # close() must not secretly serve a crashed drain


def test_concurrent_clients_all_exact():
    cfg = SortdConfig(max_batch=16, max_wait_s=0.005, max_bucket=1 << 11)
    failures = []

    def client(cid, sd):
        rng = np.random.default_rng(cid)
        pending = []
        for i in range(15):
            n = int(rng.integers(2, 3000))  # some rows oversize (> 2048)
            x = mk(n, seed=cid * 100 + i)
            pending.append((x, sd.submit(x)))
        for x, f in pending:
            if not np.array_equal(f.result(timeout=120), np.sort(x)):
                failures.append((cid, x.size))

    with Sortd(SortEngine(TOPO), cfg) as sd:
        ts = [threading.Thread(target=client, args=(c, sd)) for c in range(3)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        m = sd.metrics()
    assert not failures
    assert m["completed"] == 45
    assert 0 <= m["latency_ms"]["p50"] <= m["latency_ms"]["p99"]
    for b in m["buckets"].values():
        assert 0.0 <= b["pad_waste"] < 1.0


# ---------------------------------------------- ServeEngine empty-batch fix
def test_generate_empty_request_list_returns_empty_dict():
    """Regression: ``_pad_batch`` raised a bare ValueError (``max()`` of an
    empty sequence) when ``generate`` was called with no requests."""
    from repro.serve.engine import ServeEngine

    # __init__ only closes over cfg/api inside jit lambdas, so the guard is
    # testable without building a model.
    eng = ServeEngine.__new__(ServeEngine)
    eng.sorter = SortEngine(TOPO)
    assert ServeEngine.generate(eng, []) == {}
    assert ServeEngine.order_by_length(eng, []) == []
