"""Paper-faithful sort: correctness across the paper's distributions,
counters behaviour (Figs 6.20–6.24), cost-model sanity."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    LinkModel,
    OHHCTopology,
    model_comm_time_s,
    ohhc_sort_host,
    ohhc_sort_sim,
    parallel_quicksort_counters,
    quicksort_counters,
)
from repro.core.schedule import AccumulationSchedule
from repro.data.distributions import make_array

DISTS = ["random", "sorted", "reversed", "local"]


@pytest.mark.parametrize("dist", DISTS)
@pytest.mark.parametrize("method", ["paper", "sampled"])
def test_sim_sort_correct(dist, method):
    topo = OHHCTopology(1, "full")
    x = make_array(dist, 4096, seed=1)
    cap = 4096 if (dist in ("local",) and method == "paper") else None
    out, counts = ohhc_sort_sim(jnp.asarray(x), topo, method=method, capacity=cap)
    np.testing.assert_array_equal(np.asarray(out), np.sort(x))
    assert int(counts.sum()) == 4096


@pytest.mark.parametrize("variant", ["full", "half"])
def test_host_sort_correct(variant):
    topo = OHHCTopology(2, variant)
    x = make_array("random", 100_000, seed=2)
    r = ohhc_sort_host(x, topo)
    np.testing.assert_array_equal(r.sorted_array, np.sort(x))
    assert r.bucket_sizes.sum() == x.size
    assert r.paper_steps == 12 * topo.num_groups * 2 - 2
    assert r.t_parallel_model_s > 0


def test_paper_buckets_collapse_on_local_distribution():
    """The paper's own weakness: clustered values swamp a few buckets."""
    topo = OHHCTopology(1, "full")
    x = make_array("local", 100_000, seed=3)
    r_paper = ohhc_sort_host(x, topo, method="paper")
    r_sample = ohhc_sort_host(x, topo, method="sampled")
    imb_paper = r_paper.bucket_sizes.max() / np.mean(r_paper.bucket_sizes)
    imb_sample = r_sample.bucket_sizes.max() / np.mean(r_sample.bucket_sizes)
    assert imb_paper > 5.0  # equal-width ranges collapse
    assert imb_sample < 2.0  # sampled splitters stay balanced


def test_counters_match_paper_qualitative_findings():
    """Fig 6.22: sorted input needs far fewer swaps than random;
    Fig 6.20/6.23: iterations drop as dimension (processor count) grows."""
    x_rand = make_array("random", 20_000, seed=4).astype(np.int64)
    x_sort = np.sort(x_rand)
    c_rand = quicksort_counters(x_rand)
    c_sort = quicksort_counters(x_sort)
    assert c_sort.swaps < 0.05 * c_rand.swaps
    it = {}
    for d_h in (1, 2):
        it[d_h] = parallel_quicksort_counters(x_rand, OHHCTopology(d_h, "full")).iterations
    assert it[2] < it[1]  # more processors → smaller buckets → fewer iterations


@given(seed=st.integers(0, 1000), n=st.integers(10, 3000))
@settings(max_examples=20, deadline=None)
def test_counter_sort_is_a_sort(seed, n):
    """The instrumented quicksort's partition bookkeeping must itself sort."""
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 100, n)  # duplicates stress the partition logic
    c = quicksort_counters(x.astype(np.int64))
    assert c.recursion_calls >= 0 and c.iterations >= 0


def test_comm_model_monotonicity():
    """More data → more comm time; optical-only link slowdown increases it."""
    topo = OHHCTopology(2, "full")
    sched = AccumulationSchedule.build(topo)
    even = [100] * topo.total_procs
    t1 = model_comm_time_s(sched, even)
    t2 = model_comm_time_s(sched, [200] * topo.total_procs)
    t3 = model_comm_time_s(sched, even, LinkModel(optical_gbps=2.5))
    assert t2 > t1
    assert t3 > t1
