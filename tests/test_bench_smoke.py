"""Every benchmark suite runs end-to-end in --smoke mode and emits
schema-valid CSV (DESIGN.md §9).

One subprocess runs ``benchmarks.run --smoke`` (all suites, capped sizes —
numbers are meaningless, wiring is not), then the output is split on the
``# suite=<name>`` section markers and each suite is asserted to have
produced at least one row that parses under the
``repro.perf.schema.parse_csv_row`` contract.  A suite that crashes, goes
silent, or emits a malformed row fails its own parametrized case.
"""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.perf.schema import parse_csv_row, validate_csv

ROOT = Path(__file__).resolve().parents[1]

# Keep in sync with benchmarks/run.py SUITES (asserted below without
# importing the jax-heavy benchmark modules into the test process).
SUITE_NAMES = (
    "sequential",
    "parallel",
    "speedup_full",
    "speedup_half",
    "efficiency_full",
    "efficiency_half",
    "counters",
    "commsteps",
    "kernels",
    "moe_dispatch",
    "engine",
    "netsim",
    "verify",
    "sortd",
    "fleet",
    "faults",
    "workloads",
)


@pytest.fixture(scope="session")
def smoke_output() -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [
            sys.executable, "-m", "benchmarks.run", "--smoke",
            "--arrival", "none", "--report", "", "--fleet-report", "",
        ],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, (
        f"benchmarks.run --smoke failed (rc={proc.returncode}):\n"
        f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}"
    )
    return proc.stdout


def _sections(text: str) -> "dict[str, list[str]]":
    """Rows grouped by the preceding ``# suite=<name>`` marker."""
    sections: dict[str, list[str]] = {}
    current = None
    for line in text.splitlines():
        if line.startswith("# suite="):
            current = line.removeprefix("# suite=").strip()
            sections[current] = []
        elif line.strip() and not line.startswith("#"):
            if line.strip() == "name,us_per_call,derived":
                continue
            if current is not None:
                sections[current].append(line)
    return sections


@pytest.mark.slow
def test_run_py_suite_registry_matches(smoke_output):
    assert tuple(_sections(smoke_output)) == SUITE_NAMES


@pytest.mark.slow
@pytest.mark.parametrize("suite", SUITE_NAMES)
def test_suite_emits_schema_valid_rows(smoke_output, suite):
    rows = _sections(smoke_output).get(suite)
    assert rows, f"suite {suite!r} emitted no CSV rows in --smoke mode"
    for row in rows:
        name, us_per_call, _ = parse_csv_row(row)
        assert us_per_call >= 0.0
        # Row names are namespaced paths; they must at least not collide
        # with the marker syntax.
        assert not name.startswith("#")


@pytest.mark.slow
def test_whole_stream_validates(smoke_output):
    assert validate_csv(smoke_output) == []
