"""Training infrastructure: trainer loop, fault-injection restart,
checkpoint roundtrip + elastic reshard, compression numerics, moe dispatch
equivalence."""

import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpointer import Checkpointer
from repro.configs import registry
from repro.configs.base import MoEConfig, RunConfig, ShapeConfig
from repro.models.common import NO_SHARD
from repro.optim.compression import compress_grads, init_error_fb
from repro.train.trainer import RecoverableFailure, Trainer


def _run(tmpdir, **kw):
    cfg = registry.get_config("minitron-4b", smoke=True).replace(remat=False)
    api = registry.get_model_api(cfg)
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 4, "train"),
                    checkpoint_dir=str(tmpdir), checkpoint_every=3,
                    total_steps=30, warmup_steps=2, learning_rate=1e-3, **kw)
    return cfg, api, run


def test_loss_decreases(tmp_path):
    cfg, api, run = _run(tmp_path / "a")
    tr = Trainer(cfg, run, api)
    log = tr.run_steps(10)
    assert log[-1]["loss"] < log[0]["loss"]


def test_fault_injection_recovers(tmp_path):
    cfg, api, run = _run(tmp_path / "b")
    hits = {4, 7}

    def hook(step):
        if step in hits:
            hits.discard(step)
            raise RecoverableFailure(step)

    # sync checkpoints → deterministic recovery points (async saves can
    # race the failure, changing which checkpoint recovery lands on)
    tr = Trainer(cfg, run, api, fault_hook=hook, sync_checkpoints=True)
    log = tr.run_steps(10)
    assert tr.restarts == 2
    assert not hits  # both injected failures fired
    assert len(log) == 10
    assert np.isfinite(log[-1]["loss"])


def test_resume_from_checkpoint(tmp_path):
    cfg, api, run = _run(tmp_path / "c")
    tr = Trainer(cfg, run, api)
    tr.run_steps(7)  # checkpoints at 3, 6
    tr.ckpt.wait()
    tr2 = Trainer(cfg, run, api)
    assert int(tr2.state["step"]) == 6
    assert tr2.data.step == 6  # data pipeline state restored too


def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path / "d"), keep=2)
    tree = {"a": jnp.arange(10), "b": [jnp.ones((3, 3)), jnp.zeros(2)]}
    for s in (1, 2, 3):
        ck.save(s, tree, extra={"x": s})
    assert ck.steps() == [2, 3]  # gc keeps last 2
    skeleton = {"a": None, "b": [None, None]}
    out, extra = ck.restore(3, skeleton)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(10))
    assert extra["x"] == 3


def test_int8_compression_error_feedback_converges(rng):
    """EF makes the *accumulated* quantised gradient track the true sum."""
    g_true = jnp.asarray(rng.normal(0, 1e-4, (128,)), jnp.float32)
    fb = init_error_fb({"g": g_true})
    acc_q = jnp.zeros_like(g_true)
    for _ in range(50):
        dg, fb = compress_grads({"g": g_true}, fb)
        acc_q = acc_q + dg["g"]
    err = float(jnp.max(jnp.abs(acc_q - 50 * g_true))) / float(jnp.max(jnp.abs(50 * g_true)))
    assert err < 0.02


def test_moe_dispatch_sorted_equals_dense():
    """The paper-technique dispatch must agree with the dense oracle."""
    from repro.models import moe as MOE
    from repro.configs.base import ModelConfig

    cfg = ModelConfig(
        family="moe", d_model=32, dtype=jnp.float32, param_dtype=jnp.float32,
        moe=MoEConfig(num_experts=4, num_experts_per_tok=2, expert_d_ff=16,
                      dispatch="sorted", capacity_factor=8.0),
    )
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.float32)
    y_sorted, aux1 = MOE.apply_moe(p, x, cfg, NO_SHARD)
    cfg_d = cfg.replace(moe=cfg.moe.__class__(**{**cfg.moe.__dict__, "dispatch": "dense"}))
    y_dense, aux2 = MOE.apply_moe(p, x, cfg_d, NO_SHARD)
    np.testing.assert_allclose(np.asarray(y_sorted), np.asarray(y_dense),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


def test_elastic_mesh_shrinks_pod_axis():
    from repro.runtime.elastic import elastic_mesh

    mesh = elastic_mesh((4, 1, 1), ("pod", "data", "model"), devices=jax.devices())
    assert mesh.devices.shape == (1, 1, 1)  # 1 CPU device → pod axis shrank
    with pytest.raises(ValueError):
        elastic_mesh((1, 2, 2), ("pod", "data", "model"), devices=jax.devices())
