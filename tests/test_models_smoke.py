"""Per-arch smoke tests (assignment requirement): reduced config of the
same family, one forward + one train step on CPU, asserting output shapes
and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import RunConfig, ShapeConfig
from repro.data.pipeline import SyntheticLMData
from repro.models.common import NO_SHARD
from repro.train.train_step import init_train_state, make_train_step

ARCHS = list(registry.ARCHS)


def _batch(cfg, B=2, S=32):
    data = SyntheticLMData(cfg, B, S, seed=0)
    return data.next_batch()


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = registry.get_config(arch, smoke=True)
    api = registry.get_model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = api.forward(params, batch, cfg, NO_SHARD)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = registry.get_config(arch, smoke=True)
    api = registry.get_model_api(cfg)
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 2, "train"),
                    warmup_steps=1, total_steps=4)
    state = init_train_state(jax.random.PRNGKey(0), cfg, run, api)
    step = jax.jit(make_train_step(cfg, run, api, NO_SHARD))
    batch = _batch(cfg)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "mamba2-370m", "zamba2-2.7b",
                                  "deepseek-v2-lite-16b", "whisper-tiny"])
def test_grad_accum_matches_single_batch(arch):
    """grad_accum=2 must equal the A=1 step on the same data (linearity)."""
    cfg = registry.get_config(arch, smoke=True).replace(remat=False)
    api = registry.get_model_api(cfg)
    shape = ShapeConfig("t", 32, 4, "train")
    runs = [RunConfig(model=cfg, shape=shape, grad_accum=a, warmup_steps=1,
                      total_steps=4) for a in (1, 2)]
    batch = _batch(cfg, B=4)
    outs = []
    for run in runs:
        state = init_train_state(jax.random.PRNGKey(0), cfg, run, api)
        step = jax.jit(make_train_step(cfg, run, api, NO_SHARD))
        state, m = step(state, batch)
        outs.append(np.asarray(jax.tree.leaves(state["params"])[0], np.float32))
    np.testing.assert_allclose(outs[0], outs[1], atol=2e-3)
