"""Array Division Procedure (§3.1) properties + sampled splitters."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import partition


@given(
    n=st.integers(2, 500),
    buckets=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_paper_buckets_are_ordered(n, buckets, seed):
    """Range partitioning's invariant: every value in bucket i ≤ every value
    in bucket j for i < j — the merge-free property."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-(2**30), 2**30, n).astype(np.int32)
    ids = np.asarray(partition.paper_bucket_ids(jnp.asarray(x), buckets))
    assert ids.min() >= 0 and ids.max() < buckets
    order = np.argsort(ids, kind="stable")
    maxes = {}
    for i, b in zip(order, ids[order]):
        maxes.setdefault(b, []).append(x[i])
    keys = sorted(maxes)
    for a, b in zip(keys, keys[1:]):
        assert max(maxes[a]) <= min(maxes[b])


@given(n=st.integers(32, 2000), buckets=st.integers(2, 16))
@settings(max_examples=25, deadline=None)
def test_sampled_splitters_balance(n, buckets):
    rng = np.random.default_rng(buckets * 1000 + n)
    x = rng.normal(0, 1e6, n).astype(np.int32)  # clustered (paper's 'local')
    spl = partition.sampled_splitters(jnp.asarray(x), buckets, oversample=64)
    ids = np.asarray(partition.splitter_bucket_ids(jnp.asarray(x), spl))
    counts = np.bincount(ids, minlength=buckets)
    assert counts.max() <= max(4.0 * n / buckets, 16)


def test_scatter_unscatter_roundtrip(rng):
    x = rng.integers(0, 1 << 20, 1000).astype(np.int32)
    ids = partition.paper_bucket_ids(jnp.asarray(x), 8)
    buckets, counts = partition.scatter_to_buckets(jnp.asarray(x), ids, 8, 1000)
    assert int(counts.sum()) == 1000
    buckets = jnp.sort(buckets, axis=1)
    out = partition.unscatter(buckets, counts, 1000)
    np.testing.assert_array_equal(np.asarray(out), np.sort(x))


def test_overflow_is_detected(rng):
    x = rng.integers(0, 10, 100).astype(np.int32)  # heavy duplicates
    ids = partition.paper_bucket_ids(jnp.asarray(x), 4)
    _, counts = partition.scatter_to_buckets(jnp.asarray(x), ids, 4, 8)
    assert int(counts.sum()) < 100  # clipped counts expose the overflow


def test_ranks_are_stable(rng):
    ids = jnp.asarray(rng.integers(0, 4, 64).astype(np.int32))
    ranks = np.asarray(partition.bucket_ranks(ids, 4))
    for b in range(4):
        rb = ranks[np.asarray(ids) == b]
        np.testing.assert_array_equal(rb, np.arange(len(rb)))
