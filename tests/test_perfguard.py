"""Perf-regression gate unit tests (DESIGN.md §9).

Everything runs on fabricated records — ``record_from_measurement`` is the
test seam that turns hand-picked medians into fully normalized
:class:`~repro.perf.schema.PerfRecord` objects without timing anything —
so the classification, baseline round-trip, and normalization math are
exercised deterministically with zero benchmark execution.
"""

from __future__ import annotations

import json
import math
import subprocess
import sys
from pathlib import Path

import pytest

from repro import perf
from repro.perf.schema import TRAJECTORY_KEEP
from repro.roofline.analysis import bound_time_s
from repro.roofline.hw import HW

ROOT = Path(__file__).resolve().parents[1]

# A fixed fixture machine: 1 GB/s memory, 10 GFLOP/s compute.  All the
# numbers below are chosen against these peaks, so the expected roofline
# times are exact powers of ten.
FIX_HW = HW(
    name="fixture-hw",
    peak_bf16_flops=1e10,
    hbm_bw=1e9,
    ici_bw=1e9,
    inter_pod_bw=1e9,
    hbm_bytes=0.0,
)

# 1 MB moved, 1 kFLOP: memory-bound on FIX_HW → roofline_s = 1e6/1e9 = 1 ms.
WORKLOAD = perf.Workload(bytes_moved=1e6, flops=1e3)
ROOFLINE_S = 1e-3


def rec(
    case_id: str = "engine/sort/random/65536/int32",
    median_s: float = 2e-3,
    *,
    workload: "perf.Workload | None" = WORKLOAD,
    hw: HW = FIX_HW,
    lower: float = 0.5,
    upper: float = 0.75,
    iqr_s: float = 0.0,
) -> perf.PerfRecord:
    return perf.record_from_measurement(
        case_id=case_id,
        median_s=median_s,
        iqr_s=iqr_s,
        warmup=1,
        repeats=5,
        workload=workload,
        hw=hw,
        lower=lower,
        upper=upper,
    )


def baseline_of(*records: perf.PerfRecord, trajectory=None) -> dict:
    return perf.build_baseline(
        records,
        suite="engine",
        hw_name=FIX_HW.name,
        recorded_utc="2026-08-08T00:00:00Z",
        trajectory=trajectory,
    )


# --- normalization math ----------------------------------------------------


def test_normalize_against_roofline():
    out = perf.normalize(2e-3, WORKLOAD, FIX_HW)
    assert out["normalized"] is True
    assert out["roofline_s"] == pytest.approx(ROOFLINE_S)
    assert out["norm_ratio"] == pytest.approx(2.0)  # 2 ms vs a 1 ms bound
    assert out["pct_of_roofline"] == pytest.approx(50.0)


def test_normalize_compute_bound_term():
    # 1e8 FLOPs at 1e10 FLOP/s (10 ms) dominates 1e6 bytes at 1e9 B/s (1 ms).
    w = perf.Workload(bytes_moved=1e6, flops=1e8)
    assert perf.roofline_s(w, FIX_HW) == pytest.approx(1e-2)
    assert bound_time_s(flops=1e8, bytes_moved=1e6, hw=FIX_HW) == pytest.approx(1e-2)


def test_normalize_raw_fallback_without_workload():
    out = perf.normalize(4.2e-3, None, FIX_HW)
    assert out["normalized"] is False
    assert out["roofline_s"] is None
    assert out["norm_ratio"] == pytest.approx(4.2e-3)  # raw seconds
    assert out["pct_of_roofline"] is None


def test_roofline_rejects_empty_workload():
    with pytest.raises(ValueError):
        perf.roofline_s(perf.Workload(bytes_moved=0.0, flops=0.0), FIX_HW)


# --- classification --------------------------------------------------------


def test_classify_bands():
    kw = dict(lower=0.5, upper=0.75)
    assert perf.classify(2.0, 2.0, **kw)[0] == "pass"
    assert perf.classify(3.2, 2.0, **kw)[0] == "warn"  # 1.6x > 1 + 0.75*0.75
    assert perf.classify(3.6, 2.0, **kw)[0] == "fail"  # 1.8x > 1.75
    assert perf.classify(0.9, 2.0, **kw)[0] == "warn"  # 0.45x < 0.5 → stale?


def test_classify_asymmetric_tolerances():
    # Wide regression arm, tight improvement arm: 1.5x passes but 0.85x warns.
    kw = dict(lower=0.1, upper=1.0)
    status, rel, _ = perf.classify(1.5, 1.0, **kw)
    assert (status, rel) == ("pass", pytest.approx(1.5))
    assert perf.classify(0.85, 1.0, **kw)[0] == "warn"
    assert perf.classify(2.01, 1.0, **kw)[0] == "fail"
    # Warn band sits at WARN_FRACTION of the regression arm (1.75x here).
    assert perf.classify(1.8, 1.0, **kw)[0] == "warn"


def test_classify_slack_scales_both_arms():
    kw = dict(lower=0.5, upper=0.75)
    assert perf.classify(4.5, 2.0, **kw)[0] == "fail"
    assert perf.classify(4.5, 2.0, slack=2.0, **kw)[0] == "warn"  # 2.25x < 1+1.5
    assert perf.classify(0.9, 2.0, slack=2.0, **kw)[0] == "pass"  # lo widened


def test_classify_rejects_nonpositive_reference():
    with pytest.raises(ValueError):
        perf.classify(1.0, 0.0, lower=0.5, upper=0.75)


# --- judge: the acceptance-criterion slowdown ------------------------------


def test_injected_2x_slowdown_fails_with_roofline_delta():
    baseline = baseline_of(rec(median_s=2e-3))
    slowed = rec(median_s=4e-3)  # same case, twice the wall time
    (v,) = perf.judge([slowed], baseline)
    assert v.status == "fail"
    assert not v.gate_ok
    assert v.rel == pytest.approx(2.0)
    # The detail must carry the %-of-roofline movement: 50% → 25%.
    assert "%-of-roofline" in v.detail
    assert "50.00% -> 25.00%" in v.detail
    assert "-25.00pp" in v.detail
    assert not perf.gate_ok([v])
    assert perf.summarize([v])["fail"] == 1


def test_judge_pass_within_band():
    baseline = baseline_of(rec(median_s=2e-3))
    (v,) = perf.judge([rec(median_s=2.2e-3)], baseline)
    assert (v.status, v.gate_ok) == ("pass", True)
    assert v.rel == pytest.approx(1.1)


def test_judge_uses_baseline_tolerance_not_fresh():
    # The committed band governs: a fresh record claiming a looser band
    # cannot widen the gate it is judged under.
    baseline = baseline_of(rec(median_s=2e-3, lower=0.1, upper=0.1))
    fresh = rec(median_s=4e-3, lower=9.0, upper=9.0)
    (v,) = perf.judge([fresh], baseline)
    assert v.status == "fail"


# --- judge: new / missing / workload drift ---------------------------------


def test_judge_new_case_fails_gate():
    baseline = baseline_of(rec())
    verdicts = perf.judge([rec(), rec(case_id="engine/sort/local/65536/int32")], baseline)
    by_status = {v.status for v in verdicts}
    assert by_status == {"pass", "new"}
    assert not perf.gate_ok(verdicts)
    (new,) = [v for v in verdicts if v.status == "new"]
    assert "--update-baseline" in new.detail


def test_judge_no_baseline_all_new():
    verdicts = perf.judge([rec(), rec(case_id="engine/b")], None)
    assert [v.status for v in verdicts] == ["new", "new"]
    assert not perf.gate_ok(verdicts)


def test_judge_missing_case_fails_unless_subset():
    baseline = baseline_of(rec(), rec(case_id="engine/sort/dupes/65536/int32"))
    verdicts = perf.judge([rec()], baseline)
    assert perf.summarize(verdicts) == {
        "pass": 1, "warn": 0, "fail": 0, "new": 0, "missing": 1,
    }
    assert not perf.gate_ok(verdicts)
    # Explicit subset runs (--filter / --smoke vs a --full baseline) skip it.
    subset = perf.judge([rec()], baseline, subset=True)
    assert [v.status for v in subset] == ["pass"]
    assert perf.gate_ok(subset)


def test_judge_changed_workload_is_incomparable():
    baseline = baseline_of(rec())
    drifted = rec(workload=perf.Workload(bytes_moved=2e6, flops=1e3))
    (v,) = perf.judge([drifted], baseline)
    assert v.status == "new"
    assert "incomparable" in v.detail
    assert not v.gate_ok


def test_judge_slack_never_rescues_new_or_missing():
    baseline = baseline_of(rec(), rec(case_id="engine/gone"))
    verdicts = perf.judge(
        [rec(), rec(case_id="engine/fresh")], baseline, slack=100.0
    )
    statuses = sorted(v.status for v in verdicts)
    assert statuses == ["missing", "new", "pass"]
    assert not perf.gate_ok(verdicts)


# --- baseline round-trip & trajectory --------------------------------------


def test_update_baseline_round_trip(tmp_path):
    records = [rec(), rec(case_id="engine/sort/dupes/65536/int32", median_s=3e-3)]
    doc = baseline_of(*records)
    path = perf.baseline_path("engine", tmp_path)
    assert path.name == "BENCH_engine.json"
    perf.save_baseline(doc, path)
    loaded = perf.load_baseline(path)
    assert loaded == doc
    assert loaded["case_count"] == 2
    # Re-judging the very records that were recorded must be clean.
    verdicts = perf.judge(records, loaded)
    assert [v.status for v in verdicts] == ["pass", "pass"]
    assert all(v.rel == pytest.approx(1.0) for v in verdicts)


def test_load_baseline_rejects_unknown_schema(tmp_path):
    p = tmp_path / "BENCH_engine.json"
    p.write_text(json.dumps({"schema": 999, "cases": {}}))
    with pytest.raises(ValueError, match="schema"):
        perf.load_baseline(p)


def test_trajectory_appends_and_stays_bounded():
    doc = baseline_of(rec())
    assert len(doc["trajectory"]) == 1
    entry = doc["trajectory"][0]
    assert entry["hw"] == FIX_HW.name
    assert entry["norm_ratios"] == {
        "engine/sort/random/65536/int32": pytest.approx(2.0)
    }
    # Each --update-baseline threads the prior history through; the kept
    # window is bounded at TRAJECTORY_KEEP.
    for _ in range(TRAJECTORY_KEEP + 7):
        doc = baseline_of(rec(), trajectory=doc["trajectory"])
    assert len(doc["trajectory"]) == TRAJECTORY_KEEP


def test_reference_entry_persists_workload_and_tolerance():
    entry = perf.reference_entry(rec(median_s=2e-3, lower=0.2, upper=0.3))
    assert entry["norm_ratio"] == pytest.approx(2.0)
    assert entry["raw_s"] == pytest.approx(2e-3)
    assert entry["workload"] == {"bytes_moved": 1e6, "flops": 1e3}
    assert entry["tolerance"] == {"lower": 0.2, "upper": 0.3}
    assert entry["normalized"] is True


# --- reports ---------------------------------------------------------------


def test_markdown_and_json_reports():
    baseline = baseline_of(rec(median_s=2e-3))
    verdicts = perf.judge([rec(median_s=4e-3)], baseline)
    md = perf.markdown_report({"engine": verdicts}, hw_name=FIX_HW.name, slack=2.0)
    assert "engine/sort/random/65536/int32" in md
    assert "FAIL" in md
    assert "slack: 2x" in md
    doc = perf.json_report(
        {"engine": verdicts}, {"engine": [rec(median_s=4e-3)]},
        hw_name=FIX_HW.name, slack=2.0, elapsed_s=1.5,
    )
    assert doc["gate_ok"] is False
    assert doc["totals"]["fail"] == 1
    assert doc["suites"]["engine"]["verdicts"][0]["status"] == "fail"
    assert doc["suites"]["engine"]["records"][0]["median_s"] == pytest.approx(4e-3)
    json.dumps(doc)  # must be serializable as the CI artifact


# --- CSV row contract ------------------------------------------------------


def test_parse_csv_row_accepts_emit_format():
    name, us, derived = perf.parse_csv_row("engine/sort/random,123.4,iqr_us=1.2")
    assert name == "engine/sort/random"
    assert us == pytest.approx(123.4)
    assert derived == "iqr_us=1.2"
    # derived may itself contain commas (split is bounded at 3 fields)
    assert perf.parse_csv_row("a,1.0,x=1,y=2")[2] == "x=1,y=2"


@pytest.mark.parametrize(
    "row",
    [
        "onlyname",
        "two,fields",
        "bad name,1.0,d",
        ",1.0,d",
        "a,notanum,d",
        "a,-1.0,d",
        "a,inf,d",
        "a,nan,d",
    ],
)
def test_parse_csv_row_rejects(row):
    with pytest.raises(ValueError):
        perf.parse_csv_row(row)


def test_validate_csv_skips_markers_and_header():
    text = "name,us_per_call,derived\n# suite=engine\n\nok/row,1.0,d\nbad row,1,d\n"
    problems = perf.validate_csv(text)
    assert len(problems) == 1
    assert "line 5" in problems[0]


# --- CLI guards ------------------------------------------------------------


def _perfguard(*argv: str):
    return subprocess.run(
        [sys.executable, str(ROOT / "tools" / "perfguard.py"), *argv],
        capture_output=True, text=True, timeout=120,
    )


def test_cli_refuses_update_baseline_with_filter():
    p = _perfguard("--update-baseline", "--filter", "engine/sort")
    assert p.returncode == 2
    assert "--filter" in p.stdout


def test_cli_refuses_smoke_update_of_default_baselines():
    p = _perfguard("--smoke", "--update-baseline")
    assert p.returncode == 2
    assert "--full" in p.stdout
