"""Unsigned and narrow key dtypes end-to-end (ISSUE 3 satellite):
``estimate_stats``, the ``_sim_fill``/``_sim_low`` sentinels, bucket-id
arithmetic across signed ranges, and ``sort_many`` bucketing for
uint32/int8 — including the all-max/all-min sentinel-collision edges."""

import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import OHHCTopology, SortEngine, estimate_stats
from repro.core.engine import _sim_fill, _sim_low
from repro.core.ohhc_sort import ohhc_sort_host
from repro.data.distributions import ALL_DISTRIBUTIONS, key_space_max, make_array

pytestmark = pytest.mark.conformance

TOPO = OHHCTopology(1, "full")
NARROW = ("int8", "int16", "uint8", "uint16", "uint32")


# ------------------------------------------------------------- generator
@pytest.mark.parametrize("dtype", ("int8", "int16", "int64", "uint32", "float32"))
@pytest.mark.parametrize("dist", ALL_DISTRIBUTIONS)
def test_make_array_respects_dtype_range(dtype, dist):
    x = make_array(dist, 2000, seed=1, dtype=np.dtype(dtype))
    assert x.dtype == np.dtype(dtype)
    assert x.min() >= 0
    assert int(x.max()) <= key_space_max(dtype)
    if dist == "sorted":
        assert np.all(np.diff(x.astype(np.int64)) >= 0)  # no wrap broke the order


def test_make_array_int32_matches_historical_generator():
    # The dtype generalisation must not move the paper-default arrays.
    x = make_array("random", 1000, seed=42)
    rng = np.random.default_rng(42)
    ref = rng.integers(0, np.iinfo(np.int32).max, 1000, dtype=np.int64)
    np.testing.assert_array_equal(x, np.clip(ref, 0, np.iinfo(np.int32).max).astype(np.int32))


# ----------------------------------------------------------------- stats
@pytest.mark.parametrize("dtype", ("int8", "uint32", "float32"))
def test_estimate_stats_narrow_and_unsigned(dtype):
    x = make_array("random", 20_000, seed=2, dtype=np.dtype(dtype))
    s = estimate_stats(x, num_buckets=36)
    assert s.dtype == str(x.dtype)
    assert 0.0 < s.f_max_paper <= 1.0
    assert 0.0 < s.f_max_sampled <= 1.0
    assert s.n == x.size


def test_estimate_stats_constant_array_is_dupes():
    x = np.full(5000, np.iinfo(np.int8).max, np.int8)
    s = estimate_stats(x, num_buckets=36)
    assert s.dup_top_frac == 1.0
    assert s.label == "dupes"


# ------------------------------------------------------------- sentinels
@pytest.mark.parametrize("dtype", ("int8", "int16", "int32", "uint8", "uint32"))
def test_sim_sentinels_match_dtype_bounds(dtype):
    dt = jnp.dtype(dtype)
    fill, low = _sim_fill(dt), _sim_low(dt)
    assert fill.dtype == dt and low.dtype == dt
    assert int(fill) == np.iinfo(dtype).max
    assert int(low) == np.iinfo(dtype).min


def test_sim_sentinels_float():
    assert np.isposinf(float(_sim_fill(jnp.float32)))
    assert np.isneginf(float(_sim_low(jnp.float32)))
    assert _sim_fill(jnp.float32).dtype == jnp.float32


# -------------------------------------------------- sentinel collisions
@pytest.mark.parametrize("dtype", ("uint32", "int8", "uint8", "int16"))
@pytest.mark.parametrize("bound", ("max", "min"))
def test_engine_sorts_all_sentinel_valued_arrays(dtype, bound):
    """An array made entirely of the pad-fill value (dtype max) — or the
    low sentinel — must come back intact: validity masking, not value
    comparison, is what separates payload from padding."""
    info = np.iinfo(dtype)
    v = info.max if bound == "max" else info.min
    x = np.full(333, v, dtype=dtype)
    eng = SortEngine(TOPO)
    out = eng.sort(x)
    assert out.dtype == x.dtype
    np.testing.assert_array_equal(out, x)
    assert eng.last_report["counts_sum"] == x.size


def test_engine_sorts_max_and_min_mixture():
    info = np.iinfo(np.int8)
    x = np.tile(np.array([info.min, info.max], np.int8), 200)
    eng = SortEngine(TOPO)
    out = eng.sort(x)
    np.testing.assert_array_equal(out, np.sort(x))


# ------------------------------------------------- signed-range bucketing
@pytest.mark.parametrize("dtype", ("int8", "int16", "int32"))
@pytest.mark.parametrize("method", ("paper", "sampled"))
def test_engine_sim_handles_negative_spans(dtype, method):
    """Keys spanning the negative range: unsigned-wraparound bucket ids
    must stay exact (a native signed subtraction would overflow int8)."""
    info = np.iinfo(dtype)
    rng = np.random.default_rng(4)
    x = rng.integers(info.min, info.max, 1500, dtype=np.int64).astype(dtype)
    eng = SortEngine(TOPO)
    stats = eng.stats(x)
    from repro.core import SortPlan, autotune_capacity
    from repro.kernels import ops

    padded = ops.bucketed_length(x.size)
    cap = autotune_capacity(stats, method, TOPO.total_procs, padded)
    out = eng.sort(x, plan=SortPlan("sim", method, cap, padded, "forced"))
    np.testing.assert_array_equal(out, np.sort(x))
    assert eng.last_report["counts_sum"] == x.size


@pytest.mark.parametrize("dtype", ("int8", "int16"))
def test_host_path_handles_negative_spans(dtype):
    info = np.iinfo(dtype)
    rng = np.random.default_rng(5)
    x = rng.integers(info.min, info.max, 4000, dtype=np.int64).astype(dtype)
    r = ohhc_sort_host(x, TOPO, method="paper")
    np.testing.assert_array_equal(r.sorted_array, np.sort(x))
    assert int(r.bucket_sizes.sum()) == x.size


# -------------------------------------------------------------- sort_many
@pytest.mark.parametrize("dtype", ("uint32", "int8"))
def test_sort_many_narrow_unsigned_batches(dtype):
    eng = SortEngine(TOPO)
    xs = [
        make_array(d, n, seed=n, dtype=np.dtype(dtype))
        for d, n in zip(("random", "dupes", "sorted", "local"), (300, 900, 1024, 77))
    ]
    # include an all-max row: the sentinel-collision case inside a batch
    xs.append(np.full(256, np.iinfo(dtype).max, dtype=dtype))
    outs = eng.sort_many(xs)
    assert len(outs) == len(xs)
    for x, o in zip(xs, outs):
        assert o.dtype == x.dtype
        np.testing.assert_array_equal(o, np.sort(x))
    assert eng.trace_count == 1  # one vmapped executable for the whole batch


def test_sort_many_rejects_mixed_dtypes():
    eng = SortEngine(TOPO)
    with pytest.raises(ValueError, match="homogeneous"):
        eng.sort_many([np.zeros(8, np.int8), np.zeros(8, np.uint32)])


# ------------------------------------------------ int64 sim under jax x64
_X64_SCRIPT = r"""
import os
os.environ["JAX_ENABLE_X64"] = "1"
import numpy as np
from repro.core import OHHCTopology, SortEngine, SortPlan, autotune_capacity, x64_enabled
from repro.kernels import ops

assert x64_enabled()
topo = OHHCTopology(1, "full")
eng = SortEngine(topo)
# Adversarial large-magnitude int64 keys: distinct values above 2^53 whose
# float32 (and even float64) images collide — integer bucket ids must not.
x = (np.int64(1) << 60) + np.arange(36 * 64, dtype=np.int64)
rng = np.random.default_rng(2); rng.shuffle(x)
stats = eng.stats(x)
padded = ops.bucketed_length(x.size)
cap = autotune_capacity(stats, "paper", topo.total_procs, padded)
out = eng.sort(x, plan=SortPlan("sim", "paper", cap, padded, "forced"))
assert out.dtype == np.int64, out.dtype
assert np.array_equal(out, np.sort(x))
lo = int(x.min()); width = (int(x.max()) - lo) // 36 + 1
expected = np.bincount((x - lo) // width, minlength=36)
assert np.array_equal(eng.last_report["counts"], expected), (
    eng.last_report["counts"], expected)
print("X64_INT64_SIM_OK")
"""


@pytest.mark.slow
def test_int64_sim_bucket_ids_exact_under_x64():
    """Regression (ISSUE 3 satellite): with jax x64 on, the sim path takes
    int64 directly, and its paper bucket ids must be exact integer
    arithmetic for keys above 2^53 (where even float64 collapses)."""
    import subprocess
    import sys

    r = subprocess.run(
        [sys.executable, "-c", _X64_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd=str(pathlib.Path(__file__).parent.parent),
    )
    assert "X64_INT64_SIM_OK" in r.stdout, r.stderr[-3000:]
