"""Property-testing facade: real ``hypothesis`` when installed, else a
minimal deterministic fallback.

``hypothesis`` is a declared test dependency (pyproject ``[test]`` extra)
and CI installs it, but hermetic containers may not have it; the fallback
runs each ``@given`` test against ``max_examples`` seeded-random draws so
the property tests keep their coverage instead of skipping wholesale.

Only the strategy surface the suite uses is implemented:
``st.integers(lo, hi)`` and ``st.sampled_from(seq)``, with ``@given``
taking keyword strategies and ``@settings(max_examples=..., deadline=...)``
applied *under* ``@given`` (the order every test in this repo uses).
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class st:  # noqa: N801 - mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    def settings(max_examples: int = 10, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            n_examples = getattr(fn, "_max_examples", 10)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(0)
                for _ in range(n_examples):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            # Hide the strategy-filled parameters from pytest's fixture
            # resolution (it inspects __signature__).
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[
                    p for name, p in sig.parameters.items() if name not in strategies
                ]
            )
            return wrapper

        return deco
