"""Docs contract: every ``DESIGN.md §n`` citation in the tree resolves
(same check CI runs via ``tools/check_design_refs.py``)."""

import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_design_references_resolve():
    r = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_design_refs.py")],
        capture_output=True,
        text=True,
        timeout=60,
    )
    assert r.returncode == 0, r.stdout + r.stderr


def test_core_docs_exist():
    for name in (
        "DESIGN.md",
        "README.md",
        "benchmarks/README.md",
        "docs/GLOSSARY.md",
    ):
        assert (ROOT / name).exists(), name


def test_glossary_defines_the_paper_terms():
    text = (ROOT / "docs" / "GLOSSARY.md").read_text()
    for term in ("d_h", "Group", "Optical vs electronic hop",
                 "Array Division Procedure", "Pad waste"):
        assert term in text, term
