# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device.  Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves (see test_dist_sort.py).
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
