# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device.  Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves (see test_dist_sort.py).
import os

import numpy as np
import pytest

# Pin the segmented row-sort backend for the suite: the autotune probe is a
# *timed* head-to-head, so near-tie sizes could flip vmap↔pallas run to run
# and every first-touch (padded_n, dtype) would pay a probe's jit traces.
# Tests that exercise the pallas routing or the autotune itself override
# this explicitly (test_engine.py, test_kernels_batched.py).
os.environ.setdefault("REPRO_ROW_BACKEND", "vmap")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
