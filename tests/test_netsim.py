"""repro.net — link-simulator invariants (DESIGN.md §6).

The acceptance contract: simulated gather time matches the analytic
critical-path accounting for every (d_h ∈ {1,2,3}) × (full, half), and a
single injected optical-link fault still completes the gather with a
reported slowdown."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.ohhc_sort import model_comm_time_s
from repro.core.schedule import AccumulationSchedule
from repro.core.topology import OHHCTopology
from repro.net import (
    FaultScenario,
    GatherImpossible,
    LinkModel,
    Router,
    critical_hop_count,
    rebuild_degraded,
    simulate_gather,
    simulate_schedule,
)

DIMS = (1, 2, 3)
VARIANTS = ("full", "half")
GRID = [(d, v) for d in DIMS for v in VARIANTS]

# Stated tolerance for simulated-vs-analytic agreement: the barrier-mode
# event simulation and the closed-form store-and-forward sum must agree to
# floating-point accumulation error, not approximately.
TOL = 1e-9


# ---------------------------------------------------------------- routing
@given(d_h=st.integers(1, 3), variant=st.sampled_from(VARIANTS))
@settings(max_examples=12, deadline=None)
def test_bfs_diameter_matches_closed_form(d_h, variant):
    """OHHC diameter = 2·d_h + 3 (OTIS rule 2·d(HHC)+1 with d(HHC)=d_h+1)."""
    r = Router(OHHCTopology(d_h, variant))
    v = r.verify_diameter()
    assert v["ok"], v
    assert v["measured"] == 2 * d_h + 3
    # eccentricities are sane: master can reach everything within the
    # diameter, and no node beats half the diameter (radius bound)
    assert v["radius"] >= (v["measured"] + 1) // 2
    assert r.eccentricity(0) <= v["measured"]


@given(d_h=st.integers(1, 4), variant=st.sampled_from(VARIANTS))
@settings(max_examples=12, deadline=None)
def test_edge_counts_and_degrees_bounded(d_h, variant):
    """Property: summary counts equal the closed forms; degrees bounded."""
    t = OHHCTopology(d_h, variant)
    s = t.summary
    assert s["electrical_edges"] == t.electrical_edge_count_closed_form()
    assert s["optical_edges"] == t.optical_edge_count_closed_form()
    r = Router(t)
    max_deg = 3 + (d_h - 1) + 1  # intra-cell + hypercube + ≤1 optical
    for gid, nbrs in r.adjacency.items():
        assert 3 + (d_h - 1) <= len(nbrs) <= max_deg
        assert sum(1 for _, kind in nbrs if kind == "optical") <= 1


def test_shortest_path_hops_are_live_links():
    topo = OHHCTopology(2, "full")
    r = Router(topo)
    hops = r.shortest_path(0, topo.total_procs - 1)
    assert 0 < len(hops) <= r.expected_diameter()
    at = 0
    for u, v, kind in hops:
        assert u == at
        assert r.link_kind(u, v) == kind
        at = v
    assert at == topo.total_procs - 1


# ------------------------------------------------- Theorem 3/6 validation
@pytest.mark.parametrize("d_h,variant", GRID)
def test_unit_model_barrier_rounds_match_schedule(d_h, variant):
    """Measured makespan under unit hops = the 2·d_h+3 critical path."""
    topo = OHHCTopology(d_h, variant)
    sched = AccumulationSchedule.build(topo)
    res = simulate_gather(topo, link_model=LinkModel.unit(), barrier=True)
    assert critical_hop_count(res, 1e-6) == sched.critical_path_rounds()
    assert res.contention_events == 0  # healthy rounds use disjoint links
    assert res.messages == sched.tree_send_count()
    assert res.master_elems == topo.total_procs


@pytest.mark.parametrize("d_h,variant", GRID)
def test_unit_model_dependency_rounds(d_h, variant):
    """Dependency (wait-count) execution: the full variant attains the
    barrier critical path; the half variant finishes ONE round early —
    its optical-hole nodes (local ≥ G) receive no Phase-C payload, so the
    first D round never waits for the optical hop.  A measured-timeline
    finding the paper's per-round accounting cannot see."""
    topo = OHHCTopology(d_h, variant)
    expected = 2 * d_h + 3 if variant == "full" else 2 * d_h + 2
    res = simulate_gather(topo, link_model=LinkModel.unit())
    assert critical_hop_count(res, 1e-6) == expected


@pytest.mark.parametrize("d_h,variant", GRID)
def test_simulated_time_matches_analytic_model(d_h, variant):
    """Default byte-ful LinkModel: barrier-mode sim == Theorem-6 analytic
    store-and-forward sum (one-way) within TOL; dependency mode ≤ it."""
    topo = OHHCTopology(d_h, variant)
    sched = AccumulationSchedule.build(topo)
    chunk = 1024
    analytic = model_comm_time_s(
        sched,
        [chunk] * topo.total_procs,
        LinkModel().to_core(),
        itemsize=4,
        roundtrip=False,
    )
    res = simulate_gather(topo, chunk_sizes=chunk, barrier=True)
    assert abs(res.total_time_s - analytic) <= TOL * analytic + 1e-15
    dep = simulate_gather(topo, chunk_sizes=chunk)
    assert dep.total_time_s <= res.total_time_s + 1e-15
    # the optical phase exists and is the single whole-group-payload hop
    phases = res.phase_by_name()
    assert phases["C"].optical_bytes > 0 and phases["C"].electrical_bytes == 0


# ----------------------------------------------------------------- faults
@pytest.mark.parametrize("d_h,variant", GRID)
def test_single_optical_fault_completes_with_slowdown(d_h, variant):
    """One OTIS uplink down → reroute, full delivery, reported slowdown."""
    topo = OHHCTopology(d_h, variant)
    chunk = 1024
    healthy = simulate_gather(topo, chunk_sizes=chunk, barrier=True)
    scenario = FaultScenario.optical_link_down(1)
    faulted = simulate_gather(
        topo, router=scenario.router(topo), chunk_sizes=chunk, barrier=True
    )
    assert faulted.master_elems == healthy.master_elems  # nothing lost
    assert faulted.rerouted_messages == 1
    slowdown = faulted.total_time_s / healthy.total_time_s
    assert slowdown > 1.0  # the reroute is on the reported timeline
    # the reroute path is visibly longer than the dead direct hop
    assert faulted.hops > healthy.hops
    # FCFS link service: the lone reroute requests shared links only after
    # the direct sends released them, so no *genuine* queueing occurs
    assert faulted.contention_wait_s == 0.0


def test_link_occupancy_serialises_and_counts_contention():
    """Two same-round messages over one directed link: FCFS grants the
    link once, the second message queues — one contention event, makespan
    two unit hops."""
    from repro.core.schedule import Send

    topo = OHHCTopology(1, "full")
    rounds = (
        (
            Send((0, 1), (0, 0), "electrical", "X"),
            Send((0, 1), (0, 0), "electrical", "X"),
        ),
    )
    res = simulate_schedule(
        rounds, topo, link_model=LinkModel.unit(), chunk_sizes=1
    )
    assert res.contention_events == 1
    assert res.total_time_s == pytest.approx(2e-6)
    assert res.contention_wait_s == pytest.approx(1e-6)


def test_degraded_schedule_rebuilder_equivalent_to_reroute():
    """rebuild_degraded: explicit relay rounds, zero simulator reroutes,
    same delivery as implicit rerouting."""
    topo = OHHCTopology(2, "full")
    scenario = FaultScenario.optical_link_down(3)
    router = scenario.router(topo)
    sched = AccumulationSchedule.build(topo)
    rounds = rebuild_degraded(sched, topo, router)
    res = simulate_schedule(rounds, topo, router=router, chunk_sizes=64)
    assert res.rerouted_messages == 0  # every hop is a live direct link
    assert res.master_elems == 64 * topo.total_procs
    # the relay chain is longer than the direct hop it replaced
    assert res.hops > sched.tree_send_count()
    assert any(s.phase.endswith("+reroute") for rnd in rounds for s in rnd)


def test_failed_internal_node_is_gather_impossible():
    topo = OHHCTopology(1, "full")
    sched = AccumulationSchedule.build(topo)
    # (0,0) is the master — the ultimate destination
    router = Router(topo, failed_nodes=[topo.global_id(0, 0)])
    with pytest.raises(GatherImpossible):
        rebuild_degraded(sched, topo, router)


def test_failed_leaf_node_degrades_but_completes():
    topo = OHHCTopology(1, "full")
    sched = AccumulationSchedule.build(topo)
    # (1,5) only ever sends (Phase A round 1) — a pure leaf
    leaf = topo.global_id(1, 5)
    router = Router(topo, failed_nodes=[leaf])
    rounds = rebuild_degraded(sched, topo, router)
    res = simulate_schedule(rounds, topo, router=router, chunk_sizes=1)
    assert res.master_elems == topo.total_procs - 1  # exactly the leaf lost


def test_worker_down_scenario_cannot_be_rerouted():
    """``FaultScenario.worker_down`` (the serving fleet's vocabulary for a
    dead worker ≡ a dead group hub) kills an *internal* accumulation
    destination: unlike ``optical_link_down``, no relay chain saves the
    gather — the simulator agrees with the fleet that a dead worker must
    be drained, not routed around."""
    topo = OHHCTopology(1, "full")
    sched = AccumulationSchedule.build(topo)
    down = FaultScenario.worker_down(1)
    assert down.name == "worker1_down"
    assert (1, 0) in down.failed_nodes
    assert down.failed_links == (((1, 0), (0, 1)),)
    with pytest.raises(GatherImpossible):
        rebuild_degraded(sched, topo, down.router(topo))
    # the contrast case: only the uplink down — reroute succeeds
    rerouted = rebuild_degraded(
        sched, topo, FaultScenario.optical_link_down(1).router(topo)
    )
    assert any(s.phase.endswith("+reroute") for rnd in rerouted for s in rnd)


def test_worker_down_group_zero_is_the_master_hub():
    """Worker 0 maps to the master's own hub: no uplink to fail (the OTIS
    self-transpose hole), and the gather is trivially impossible."""
    topo = OHHCTopology(1, "full")
    down = FaultScenario.worker_down(0)
    assert down.failed_links == () and down.failed_nodes == ((0, 0),)
    with pytest.raises(GatherImpossible):
        rebuild_degraded(AccumulationSchedule.build(topo), topo, down.router(topo))
    with pytest.raises(ValueError):
        FaultScenario.worker_down(-1)


def test_repeated_source_in_one_round_conserves_elements():
    """A caller-supplied round with two sends from one source must not
    double-count the payload: the second send carries 0 (drain-at-read)."""
    from repro.core.schedule import Send

    topo = OHHCTopology(1, "full")
    rounds = (
        (
            Send((1, 0), (0, 1), "optical", "X"),
            Send((1, 0), (1, 1), "electrical", "X"),
        ),
    )
    res = simulate_schedule(rounds, topo, chunk_sizes=5)
    total = 5 * topo.total_procs
    # conservation: delivery moved chunks around but created none
    assert res.messages == 2
    delivered = sum(tr.elems for tr in res.traces)
    assert delivered == 5  # (1,0)'s payload once, not twice


def test_unit_link_model_report_is_strict_json():
    import json

    from repro.net import netsim_report, write_json

    r = netsim_report(dims=(1,), variants=("full",), link_model=LinkModel.unit())
    p = write_json(r, "/tmp/netsim-unit-report.json")
    parsed = json.loads(p.read_text(), parse_constant=lambda c: (_ for _ in ()).throw(ValueError(c)))
    assert parsed["link_model"]["electrical"]["gbps"] == "inf"


# ------------------------------------------------------------ engine hook
def test_sort_engine_attaches_comm_sim_estimate():
    import types

    import numpy as np

    from repro.core.engine import SortEngine

    eng = SortEngine()
    t1 = eng.comm_cost_estimate(4096)
    assert t1 > 0
    assert eng.comm_cost_estimate(4096) == t1  # cached per size bucket
    # a dist-path plan carries the simulated comm-cost estimate
    eng.mesh = types.SimpleNamespace(
        devices=np.zeros((2, 2)), axis_names=("pod", "data")
    )
    eng.axis_names = ("pod", "data")
    plan = eng.plan(np.arange(1 << 12, dtype=np.int32))
    assert plan.path == "dist"
    assert plan.comm_sim_s is not None and plan.comm_sim_s > 0
