"""Dry-run machinery on a small fake-device mesh (subprocess — the main
process keeps 1 device).  Exercises lower+compile+cost extraction for one
train and one decode cell on a (2,2,2) pod/data/model mesh with smoke
configs, plus collective-byte parsing and hierarchical psum."""

import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.configs import registry
from repro.configs.base import RunConfig, ShapeConfig
from repro.launch import sharding as SH
from repro.models.common import AxisRules
from repro.roofline.analysis import collective_bytes, roofline_from_compiled
from repro.train.train_step import make_train_step, init_train_state

mesh = compat.make_mesh((2,2,2), ("pod","data","model"))
cfg = registry.get_config("minitron-4b", smoke=True)
shape = ShapeConfig("t", 32, 8, "train")
rules = SH.rules_for(cfg, shape, mesh)
api = registry.get_model_api(cfg)
run = RunConfig(model=cfg, shape=shape, grad_accum=2)
key = jax.random.PRNGKey(0)
from repro.optim.adamw import adamw_init
state_shape = jax.eval_shape(lambda: {"params": api.init(key,cfg), "opt": adamw_init(api.init(key,cfg)), "step": jnp.zeros((),jnp.int32)})
pspecs = SH.sanitize_specs(api.param_specs(cfg, rules, 2), jax.eval_shape(lambda: api.init(key,cfg)), mesh)
sspecs = {"params": pspecs, "opt": {"m": pspecs, "v": pspecs, "count": P()}, "step": P()}
in_specs = registry.input_specs(cfg, shape)
bspecs = SH.sanitize_specs(SH.batch_specs(cfg, shape, rules), in_specs, mesh)
with compat.set_mesh(mesh):
    step = make_train_step(cfg, run, api, rules)
    jitted = jax.jit(step, in_shardings=(SH.named(sspecs,mesh), SH.named(bspecs,mesh)),
                     out_shardings=(SH.named(sspecs,mesh), None), donate_argnums=(0,))
    lowered = jitted.lower(state_shape, in_specs)
    compiled = lowered.compile()
    r = roofline_from_compiled(compiled, num_devices=8, pod_block=4)
    assert r["flops_per_device"] > 0
    assert r["collective_bytes"]["total"] > 0, "sharded train step must communicate"
    assert r["memory_analysis"]["total_bytes"] > 0
    print("train cell ok; dominant:", r["dominant"], "coll inter:", r["collective_bytes"]["inter_pod"])

# hierarchical psum: inter-pod bytes must drop vs flat psum
from repro.runtime.collectives import hierarchical_psum
def flat(x): return jax.lax.psum(x, ("data","pod"))
def hier(x): return hierarchical_psum(x, fast_axis="data", slow_axis="pod")
xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
cb = {}
for name, fn in [("flat", flat), ("hier", hier)]:
    f = compat.shard_map(fn, mesh=mesh, in_specs=P(None, "model"), out_specs=P(None, "model"))
    comp = jax.jit(f).lower(xs).compile()
    cb[name] = collective_bytes(comp.as_text(), num_devices=8, pod_block=4)
print("flat inter:", cb["flat"]["inter_pod"], "hier inter:", cb["hier"]["inter_pod"])
assert cb["hier"]["inter_pod"] < cb["flat"]["inter_pod"] or cb["flat"]["inter_pod"] == 0
print("DRYRUN_SMALL_OK")
"""


@pytest.mark.slow
def test_dryrun_small_mesh():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd="/root/repo",
    )
    assert "DRYRUN_SMALL_OK" in r.stdout, (r.stdout[-2000:], r.stderr[-3000:])
