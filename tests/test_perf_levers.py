"""§Perf levers must be numerics-preserving (they only change layout/dtype
of intermediates): ring window cache, sharded MoE dispatch buffer, bf16
attention matmuls (loose tol), master weights."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import MoEConfig, ModelConfig, RunConfig, ShapeConfig
from repro.models.common import NO_SHARD


def _serve_outputs(cfg, api, params, toks, S):
    cache = api.init_cache(cfg, toks.shape[0], S + 4)
    last, cache = api.prefill(params, {"tokens": toks[:, : S - 2]}, cfg, NO_SHARD, cache)
    lg1, cache = api.decode_step(params, toks[:, S - 2 : S - 1], cfg, NO_SHARD, cache, S - 2)
    lg2, cache = api.decode_step(params, toks[:, S - 1 : S], cfg, NO_SHARD, cache, S - 1)
    return [np.asarray(last), np.asarray(lg1), np.asarray(lg2)]


def test_ring_window_cache_exact():
    cfg = registry.get_config("mixtral-8x22b", smoke=True).replace(
        dtype=jnp.float32, remat=False
    )
    api = registry.get_model_api(cfg)
    B, S = 2, 48  # prompt longer than the 32-token smoke window
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full = _serve_outputs(cfg, api, params, toks, S)
    ring = _serve_outputs(cfg.replace(decode_window_cache=True), api, params, toks, S)
    for a, b in zip(full, ring):
        np.testing.assert_allclose(a, b, atol=1e-3)


def test_ring_cache_rejects_global_layers():
    cfg = registry.get_config("gemma3-4b", smoke=True).replace(decode_window_cache=True)
    api = registry.get_model_api(cfg)
    with pytest.raises(ValueError):
        api.init_cache(cfg, 2, 64)


def test_moe_dispatch_sharded_same_numerics():
    from repro.models import moe as MOE

    cfg = ModelConfig(
        family="moe", d_model=32, dtype=jnp.float32, param_dtype=jnp.float32,
        moe=MoEConfig(num_experts=8, num_experts_per_tok=2, expert_d_ff=16,
                      dispatch="sorted", capacity_factor=8.0),
    )
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    y0, _ = MOE.apply_moe(p, x, cfg, NO_SHARD)
    cfg2 = cfg.replace(moe=MoEConfig(num_experts=8, num_experts_per_tok=2,
                                     expert_d_ff=16, dispatch="sorted",
                                     capacity_factor=8.0, dispatch_sharded=True,
                                     expert_parallel=True))
    y1, _ = MOE.apply_moe(p, x, cfg2, NO_SHARD)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-6)


def test_attn_matmul_bf16_close_to_f32():
    cfg = registry.get_config("minitron-4b", smoke=True).replace(
        dtype=jnp.float32, remat=False
    )
    api = registry.get_model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    l0, _ = api.forward(params, {"tokens": toks}, cfg, NO_SHARD)
    l1, _ = api.forward(params, {"tokens": toks}, cfg.replace(attn_matmul_bf16=True), NO_SHARD)
    # bf16 matmuls with f32 accumulation: relative error ~1e-2 on logits
    rel = np.max(np.abs(np.asarray(l0) - np.asarray(l1))) / (np.max(np.abs(np.asarray(l0))) + 1e-9)
    assert rel < 5e-2, rel


def test_master_weights_training_converges():
    from repro.data.pipeline import SyntheticLMData
    from repro.train.train_step import init_train_state, make_train_step

    cfg = registry.get_config("minitron-4b", smoke=True).replace(remat=False)
    api = registry.get_model_api(cfg)
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 4, "train"),
                    master_weights=True, warmup_steps=1, total_steps=10,
                    learning_rate=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, run, api)
    assert jax.tree.leaves(state["params"])[0].dtype == jnp.bfloat16
    assert jax.tree.leaves(state["opt"]["master"])[0].dtype == jnp.float32
    step = jax.jit(make_train_step(cfg, run, api, NO_SHARD))
    data = SyntheticLMData(cfg, 4, 32)
    losses = []
    for _ in range(8):
        state, m = step(state, data.next_batch())
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
