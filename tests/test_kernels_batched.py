"""Fused batched row-sort kernel vs the ``np.sort`` row oracle.

Covers the ``kernels/batched.py`` contract the engine's segment path rides
(DESIGN.md §2, §8): dtype sweep × row lengths straddling the pow2 shape
buckets × adversarial row classes (all-equal and dtype-max sentinel-tie
rows), both compare-exchange variants, plus the pairs kernel's
payload-conservation guarantee.  The verify grid owns the same cells for
drift detection (``repro.verify.grid.segment_smoke_grid``); these are the
fast in-process checks.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import batched, ops


def _pack(rows, L, dtype):
    B = len(rows)
    mat = np.zeros((B, L), dtype)
    lens = np.zeros(B, np.int32)
    for i, r in enumerate(rows):
        mat[i, : len(r)] = r
        lens[i] = len(r)
    return mat, lens


def _sentinel(dtype):
    return np.iinfo(dtype).max if np.issubdtype(dtype, np.integer) else np.inf


def _check_rows(out, rows, lens, dtype):
    for b, r in enumerate(rows):
        np.testing.assert_array_equal(out[b, : lens[b]], np.sort(r))
        assert (out[b, lens[b] :] == _sentinel(dtype)).all()


@pytest.mark.parametrize("dtype", [np.int32, np.uint32, np.int16, np.float32])
@pytest.mark.parametrize("method", ["bitonic", "bitonic2op"])
def test_batched_row_sort_bucket_straddle(dtype, method, rng):
    # lengths straddling the pow2 buckets: 127/128/129 around one boundary,
    # plus 0, 1, and a full row — all packed into one L=256 batch
    L = 256
    lengths = [0, 1, 127, 128, 129, 255, 256]
    rows = []
    for n in lengths:
        if np.issubdtype(dtype, np.integer):
            info = np.iinfo(dtype)
            rows.append(rng.integers(info.min, info.max, n).astype(dtype))
        else:
            rows.append(rng.normal(size=n).astype(dtype))
    mat, lens = _pack(rows, L, dtype)
    out = np.asarray(
        batched.batched_row_sort(
            jnp.asarray(mat), jnp.asarray(lens), method=method, interpret=True
        )
    )
    _check_rows(out, rows, lens, dtype)


@pytest.mark.parametrize("dtype", [np.int32, np.uint32, np.int16])
def test_batched_row_sort_sentinel_and_equal_rows(dtype, rng):
    # adversarial classes: all-equal rows, all-sentinel rows, and mixed
    # sentinel-tie rows — the pad fill must stay distinguishable via length
    hi = np.iinfo(dtype).max
    L = 128
    rows = [
        np.full(100, hi, dtype),                     # every key == sentinel
        np.full(77, 42, dtype),                      # all equal
        np.where(rng.random(128) < 0.5, hi, hi - 1).astype(dtype),  # tie mix
    ]
    mat, lens = _pack(rows, L, dtype)
    for method in batched.METHODS:
        out = np.asarray(
            batched.batched_row_sort(
                jnp.asarray(mat), jnp.asarray(lens), method=method, interpret=True
            )
        )
        _check_rows(out, rows, lens, dtype)


@given(seed=st.integers(0, 1000), lbits=st.integers(7, 12))
@settings(max_examples=10, deadline=None)
def test_batched_row_sort_property(seed, lbits):
    # random (B, L) batches over the serving bucket range vs the row oracle
    rng = np.random.default_rng(seed)
    L = 1 << lbits
    B = int(rng.integers(1, 9))
    mat = rng.integers(0, 1 << 30, (B, L)).astype(np.int32)
    lens = rng.integers(0, L + 1, B).astype(np.int32)
    method = ("bitonic", "bitonic2op")[seed % 2]
    out = np.asarray(
        batched.batched_row_sort(
            jnp.asarray(mat), jnp.asarray(lens), method=method, interpret=True
        )
    )
    for b in range(B):
        np.testing.assert_array_equal(
            out[b, : lens[b]], np.sort(mat[b, : lens[b]])
        )


@pytest.mark.parametrize("dtype", [np.int32, np.uint32])
def test_batched_row_sort_pairs_conserves_payloads(dtype, rng):
    # pairs variant: payloads survive sentinel-tie rows (the bug class the
    # tagged compare-exchange exists for) and pair with their keys
    hi = np.iinfo(dtype).max
    B, L = 5, 256
    k = np.where(rng.random((B, L)) < 0.5, hi, hi - 1).astype(dtype)
    v = rng.integers(1, 1 << 30, (B, L)).astype(np.int32)
    lens = np.array([256, 0, 100, 255, 1], np.int32)
    ok, ov = batched.batched_row_sort_pairs(
        jnp.asarray(k), jnp.asarray(v), jnp.asarray(lens), interpret=True
    )
    ok, ov = np.asarray(ok), np.asarray(ov)
    for b in range(B):
        n = lens[b]
        np.testing.assert_array_equal(ok[b, :n], np.sort(k[b, :n]))
        # payload multiset conserved per row, zeros only in the pad tail
        np.testing.assert_array_equal(np.sort(ov[b, :n]), np.sort(v[b, :n]))
        assert (ov[b, n:] == 0).all()
        # key-consistent pairing inside each key group (bitonic is unstable)
        for key in np.unique(k[b, :n]):
            np.testing.assert_array_equal(
                np.sort(ov[b, :n][ok[b, :n] == key]),
                np.sort(v[b, :n][k[b, :n] == key]),
            )


def test_batched_row_sort_rejects_bad_shapes(rng):
    x = jnp.zeros((2, 192), jnp.int32)  # 192 not a pow2 multiple of 128
    with pytest.raises(ValueError, match="power-of-two"):
        batched.batched_row_sort(x, jnp.zeros((2,), jnp.int32), interpret=True)
    with pytest.raises(ValueError, match="method"):
        batched.batched_row_sort(
            jnp.zeros((2, 128), jnp.int32),
            jnp.zeros((2,), jnp.int32),
            method="nope",
            interpret=True,
        )


def test_engine_buckets_are_kernel_compatible():
    # every engine row bucket the segment path can emit is a valid kernel
    # shape — the routing contract between ops.bucketed_length and batched
    for n in (1, 100, 128, 1000, 8192):
        L = ops.bucketed_length(n)
        assert L % 128 == 0 and L & (L - 1) == 0
