"""Accumulation-schedule invariants: Theorem 3 accounting, wait constants,
spanning-tree property, critical path."""

import pytest

from repro.core.schedule import AccumulationSchedule, payload_bytes_per_round
from repro.core.topology import OHHCTopology


@pytest.mark.parametrize("d_h", [1, 2, 3, 4])
@pytest.mark.parametrize("variant", ["full", "half"])
def test_master_receives_everything(d_h, variant):
    topo = OHHCTopology(d_h, variant)
    s = AccumulationSchedule.build(topo)
    sim = s.simulate_chunk_counts()
    assert sim["master_final_chunks"] == topo.total_procs
    # every processor except the master sends exactly once: a spanning tree
    assert s.tree_send_count() == topo.total_procs - 1


@pytest.mark.parametrize("d_h", [1, 2, 3, 4])
@pytest.mark.parametrize("variant", ["full", "half"])
def test_theorem_3_accounting(d_h, variant):
    """The paper's 12·G·d_h−2 matches the tree for d_h ∈ {1,2} and
    *undercounts* for d_h ≥ 3 (each dimension doubles the HHC cells but the
    theorem charges 6 steps per dimension) — a reproduction finding."""
    topo = OHHCTopology(d_h, variant)
    s = AccumulationSchedule.build(topo)
    paper_one_way = 6 * topo.num_groups * d_h - 1
    ours_one_way = s.tree_send_count()
    if d_h <= 2:
        assert paper_one_way == ours_one_way
        assert s.paper_step_count() == s.roundtrip_send_count()
    else:
        assert paper_one_way < ours_one_way


@pytest.mark.parametrize("d_h", [1, 2, 3])
def test_wait_constants_match_fig_3_4(d_h):
    """G=P: normal=P+1, aggregate=2(P+1), head=6(P+1), master=5(P+1)+1."""
    topo = OHHCTopology(d_h, "full")
    s = AccumulationSchedule.build(topo)
    sim = s.simulate_chunk_counts()
    wc, expect = sim["wait_counts"], s.paper_wait_constants()
    assert wc[(0, 5)] == expect["normal"]
    assert wc[(0, 1)] == expect["aggregate"]
    assert wc[(0, 2)] == expect["aggregate"]
    if d_h > 1:
        assert wc[(0, 6)] == expect["head"]  # head of cell 1 in group 0
    assert sim["held_after"][(0, 0)] == topo.total_procs
    # master = 5(P+1)+1 appears as the total the master holds after its last
    # wait in d_h=1 (no hypercube step)
    if d_h == 1:
        assert sim["held_after"][(0, 0)] == expect["master"]


@pytest.mark.parametrize("d_h", [1, 2, 3, 4])
def test_critical_path(d_h):
    topo = OHHCTopology(d_h, "full")
    s = AccumulationSchedule.build(topo)
    # 2 (intra-HHC) + (d_h−1) (cube) + 1 (optical) + 2 + (d_h−1)
    # = 2·d_h + 3 — exactly Theorem 6's diameter-based link count
    # (2·d_h + 3), i.e. the schedule achieves the topology's diameter.
    assert s.critical_path_rounds() == 2 * d_h + 3


def test_payload_accounting():
    topo = OHHCTopology(2, "full")
    s = AccumulationSchedule.build(topo)
    sizes = [7] * topo.total_procs
    rounds = payload_bytes_per_round(s, sizes, itemsize=4)
    total = sum(r["electrical_bytes"] + r["optical_bytes"] for r in rounds)
    # every chunk crosses ≥1 link; total link-bytes ≥ all chunks' bytes
    assert total >= topo.total_procs * 7 * 4
    # optical rounds exist and carry whole group payloads
    opt = [r for r in rounds if r["optical_bytes"]]
    assert len(opt) == 1
    assert opt[0]["max_msg_bytes"] == topo.procs_per_group * 7 * 4
