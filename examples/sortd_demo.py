"""sortd demo: the micro-batching sort service end to end (DESIGN.md §8).

Three client threads submit a mixed stream of sort requests — several
dtypes, lengths spanning multiple shape buckets, plus oversize requests
that exceed the largest coalescible bucket — while the single sortd worker
coalesces them into fused ``SortEngine.sort_segments`` device calls.
Every result is checked against ``np.sort``, then the service's own
metrics (latency percentiles, pad waste and batch shape per bucket, flush
reasons) are printed.

    PYTHONPATH=src python examples/sortd_demo.py
"""

import sys
import threading

sys.path.insert(0, "src")

import numpy as np

from repro.core import SortEngine
from repro.data.distributions import ALL_DISTRIBUTIONS, make_array
from repro.serve import Sortd, SortdConfig

CLIENTS = 3
REQUESTS_PER_CLIENT = 25
DTYPES = ("int32", "int16", "float32")


def client(cid: int, sd: Sortd, failures: list):
    # Submit the whole stream asynchronously, then collect: in-flight
    # requests are what the coalescer batches — a strictly synchronous
    # caller can only ever see batches of one.
    rng = np.random.default_rng(cid)
    inflight = []
    for i in range(REQUESTS_PER_CLIENT):
        dist = ALL_DISTRIBUTIONS[int(rng.integers(len(ALL_DISTRIBUTIONS)))]
        dtype = np.dtype(DTYPES[cid % len(DTYPES)])
        if rng.random() < 0.05:  # oversize → direct engine path
            n = int(rng.integers(5000, 8000))
        else:
            n = int(rng.integers(16, 3000))
        x = make_array(dist, n, seed=cid * 1000 + i, dtype=dtype)
        inflight.append((i, dist, dtype, x, sd.submit(x)))
    for i, dist, dtype, x, fut in inflight:
        out = fut.result(timeout=120)
        if not np.array_equal(out, np.sort(x)):
            failures.append((cid, i, dist, dtype.name, x.size))


def run_wave(eng: SortEngine, cfg: SortdConfig, failures: list) -> dict:
    with Sortd(eng, cfg) as sd:
        threads = [
            threading.Thread(target=client, args=(c, sd, failures))
            for c in range(CLIENTS)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return sd.metrics()


def main():
    cfg = SortdConfig(max_batch=32, max_wait_s=0.005, max_bucket=1 << 12)
    eng = SortEngine()
    failures: list = []
    # Wave 1 pays every first-call compilation; wave 2 rides the engine's
    # shape-bucketed jit cache (shared across service instances) — the
    # steady-state latencies a long-running sortd serves at.
    cold = run_wave(eng, cfg, failures)
    m = run_wave(eng, cfg, failures)
    assert not failures, failures
    print(f"cold wave: p50={cold['latency_ms']['p50']:.1f}ms "
          f"p99={cold['latency_ms']['p99']:.1f}ms (includes jit compiles); "
          f"warm wave below")
    total = CLIENTS * REQUESTS_PER_CLIENT
    assert m["completed"] == total, m

    print(f"sortd: {total} requests from {CLIENTS} clients, all match np.sort")
    print(f"engine executables traced: {eng.trace_count} "
          f"(shape-bucketed warm cache over every (dtype, length, batch) mix)")
    print(f"flushes: {m['flushes']}  oversize-direct: {m['oversize_direct']}")
    print(f"overall latency p50={m['latency_ms']['p50']:.1f}ms "
          f"p99={m['latency_ms']['p99']:.1f}ms")
    print(f"{'bucket':>16} {'reqs':>5} {'batches':>7} {'mean_B':>6} "
          f"{'p50_ms':>8} {'p99_ms':>8} {'pad_waste':>9}")
    for bucket, b in sorted(m["buckets"].items()):
        print(f"{bucket:>16} {b['requests']:>5} {b['batches']:>7} "
              f"{b['mean_batch']:>6.1f} {b['p50_ms']:>8.1f} "
              f"{b['p99_ms']:>8.1f} {b['pad_waste']:>9.3f}")


if __name__ == "__main__":
    main()
