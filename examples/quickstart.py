"""Quickstart: the paper's parallel Quick Sort on the OHHC, end to end.

Runs the faithful algorithm (value-range buckets → per-processor bitonic
local sort → 3-phase hierarchical accumulation) on a 1-D full OHHC
(36 processors), validates the result, and prints the schedule facts the
paper proves analytically (Theorems 3/6).

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import (
    AccumulationSchedule,
    OHHCTopology,
    SortEngine,
    ohhc_sort_host,
    ohhc_sort_sim,
)
from repro.data.distributions import ALL_DISTRIBUTIONS, make_array
from repro.kernels import ops


def main():
    topo = OHHCTopology(d_h=1, variant="full")
    print(f"OHHC d_h=1 G=P: {topo.num_groups} groups × {topo.procs_per_group} "
          f"processors = {topo.total_procs} (Table 1.1)")

    x = make_array("random", 1 << 16, seed=0)

    # simulated-processor path with the Pallas bitonic local sort
    out, counts = ohhc_sort_sim(
        jnp.asarray(x), topo, local_sort=ops.make_local_sort()
    )
    assert np.array_equal(np.asarray(out), np.sort(x))
    print(f"sorted {x.size} ints; bucket imbalance max/mean = "
          f"{float(counts.max())/float(counts.mean()):.2f}")

    # schedule facts
    s = AccumulationSchedule.build(topo)
    print(f"Theorem 3 steps: paper formula={s.paper_step_count()}, "
          f"spanning-tree roundtrip={s.roundtrip_send_count()}")
    print(f"critical path rounds={s.critical_path_rounds()} "
          f"(= topology diameter 2·d_h+3 = {2*topo.d_h+3})")

    # full-size host path with per-bucket timing + comm model
    r = ohhc_sort_host(make_array("random", 1 << 20, seed=1), topo)
    print(f"1M-element host run: slowest bucket sort "
          f"{r.local_sort_times_s.max()*1e3:.2f} ms, modelled comm "
          f"{r.comm_model_time_s*1e3:.3f} ms, T_P={r.t_parallel_model_s*1e3:.2f} ms")

    # the unified engine: stats → path/method dispatch + capacity autotune
    # (DESIGN.md §4) — no hand-picked method or capacity anywhere.
    eng = SortEngine(topo)
    for dist in ALL_DISTRIBUTIONS:
        x = make_array(dist, 50_000, seed=2)
        out = eng.sort(x)
        assert np.array_equal(out, np.sort(x))
        rep = eng.last_report
        print(f"engine[{dist:>8}]: path={rep['plan'].path} "
              f"method={rep['plan'].method} "
              f"capacity={rep.get('capacity_used', '-')} "
              f"label={rep['stats'].label}")

    # batched traffic: one vmapped executable sorts the whole request batch
    outs = eng.sort_many([make_array("random", n, seed=n)
                          for n in (900, 1500, 2000)])
    assert all(np.all(np.diff(o) >= 0) for o in outs)
    print(f"sort_many: {len(outs)} requests, {eng.trace_count} total traces "
          f"this session (shape-bucketed warm cache)")


if __name__ == "__main__":
    main()
