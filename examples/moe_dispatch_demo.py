"""The paper's technique inside an ML model: sort-based MoE dispatch.

Shows routing-as-bucket-sort: top-k expert choice → bucket histogram +
stable ranks (the Array Division Procedure with SubDivider=1) → contiguous
(expert, capacity) buffer → grouped FFN → weighted combine; verified
against the dense one-hot oracle.

    PYTHONPATH=src python examples/moe_dispatch_demo.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import partition
from repro.models import moe as MOE
from repro.models.common import NO_SHARD


def main():
    cfg = ModelConfig(
        family="moe", d_model=64, dtype=jnp.float32, param_dtype=jnp.float32,
        moe=MoEConfig(num_experts=8, num_experts_per_tok=2, expert_d_ff=128,
                      dispatch="sorted", capacity_factor=2.0),
    )
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64), jnp.float32)

    # peek at the routing-as-bucketing internals
    top_p, top_e, aux = MOE._router(p, x, cfg)
    flat = top_e.reshape(-1)
    counts = partition.bucket_counts(flat, 8)
    print("expert bucket populations:", np.asarray(counts),
          f"(aux load-balance loss {float(aux):.4f})")

    y_sorted, _ = MOE.apply_moe(p, x, cfg, NO_SHARD)
    cfg_dense = cfg.replace(moe=MoEConfig(num_experts=8, num_experts_per_tok=2,
                                          expert_d_ff=128, dispatch="dense"))
    y_dense, _ = MOE.apply_moe(p, x, cfg_dense, NO_SHARD)
    err = float(jnp.max(jnp.abs(y_sorted - y_dense)))
    print(f"sorted dispatch vs dense oracle: max |Δ| = {err:.2e}")
    assert err < 1e-4


if __name__ == "__main__":
    main()
