"""Serve a small model with batched requests.

Batch formation sorts requests by prompt length with the bitonic pair-sort
kernel (the paper's primitive in its serving role), then prefill + greedy
decode with a padded KV cache.

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import registry
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = registry.get_config("gemma3-4b", smoke=True)
    api = registry.get_model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, api, max_len=128)

    rng = np.random.default_rng(0)
    lengths = [3, 21, 9, 33, 5, 14, 27, 8]
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size, ln).astype(np.int32),
                max_new_tokens=12)
        for i, ln in enumerate(lengths)
    ]
    ordered = eng.order_by_length(reqs)
    print("batch order after length sort:", [len(r.prompt) for r in ordered])
    out = eng.generate(reqs)
    for rid in sorted(out):
        print(f"request {rid} (prompt {lengths[rid]:2d} toks) -> {out[rid]}")


if __name__ == "__main__":
    main()
