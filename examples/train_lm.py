"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

A gemma3-family model scaled to ~100M params, synthetic zipf token stream,
full production stack: AdamW + cosine schedule, per-layer remat + layer
scan, checkpoint every 50 steps (atomic, async), auto-resume on restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.configs import registry
from repro.train.trainer import Trainer


def config_100m() -> ModelConfig:
    return ModelConfig(
        name="gemma-100m",
        family="dense",
        num_layers=8,
        d_model=768,
        num_heads=12,
        num_kv_heads=4,
        d_ff=3072,
        vocab_size=32768,
        qk_norm=True,
        window_pattern=(256, 256, 0),
        max_seq_len=2048,
        attn_chunk=256,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = config_100m()
    api = registry.get_model_api(cfg)
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("train", args.seq, args.batch, "train"),
        learning_rate=6e-4,
        warmup_steps=30,
        total_steps=args.steps,
        checkpoint_dir=args.ckpt,
        checkpoint_every=50,
    )
    tr = Trainer(cfg, run, api)
    n = sum(x.size for x in jax.tree.leaves(tr.state["params"]))
    print(f"{cfg.name}: {n/1e6:.1f}M params, {args.steps} steps, "
          f"batch {args.batch}×{args.seq}")
    start = int(tr.state["step"])
    if start:
        print(f"resumed from checkpoint at step {start}")
    log = tr.run_steps(args.steps - start)
    for m in log[:: max(len(log) // 10, 1)]:
        print(f"  step {m['step']:4d} loss {m['loss']:.4f} "
              f"acc {m['accuracy']:.3f} lr {m['lr']:.2e} {m['wall_s']*1e3:.0f}ms")
    print(f"final loss {log[-1]['loss']:.4f} (from {log[0]['loss']:.4f}); "
          f"stragglers flagged: {len(tr.straggler_steps)}")


if __name__ == "__main__":
    main()
