"""fleet demo: multi-worker serving with a mid-load chaos kill (DESIGN.md §10).

A 4-worker :class:`repro.serve.SortdFleet` serves a closed-loop request
mix (three shape buckets + oversize tail) while a deterministic
:class:`repro.serve.ChaosConfig` crashes the busiest worker a third of
the way in.  The health monitor detects the crash, the dead worker's
backlog is re-admitted to the survivors, and every result is checked
against ``np.sort`` — a dead worker costs latency, never an answer.
The fleet's report (routing, failover counters, per-worker metrics, the
matching ``net.faults`` scenario name) is printed at the end.

    PYTHONPATH=src python examples/fleet_demo.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.serve import ChaosConfig, FleetConfig, SortdFleet
from repro.serve.fleet.loadgen import drive_closed_loop, request_mix

N_REQUESTS = 240
CLIENTS = 8


def main() -> int:
    reqs = request_mix(N_REQUESTS, seed=11)
    chaos = ChaosConfig(name="demo-kill", kill_worker_after=N_REQUESTS // 3)
    print(f"fleet: 4 workers, {CLIENTS} closed-loop clients, "
          f"{N_REQUESTS} requests; chaos kills the busiest worker after "
          f"{chaos.kill_worker_after} admissions\n")
    with SortdFleet(FleetConfig(workers=4), chaos=chaos) as fleet:
        wall, outs = drive_closed_loop(fleet.submit, reqs, clients=CLIENTS)
        rep = fleet.report()

    wrong = sum(
        0 if np.array_equal(o, np.sort(r)) else 1 for o, r in zip(outs, reqs)
    )
    f = rep["fleet"]
    print(f"served {f['completed']}/{N_REQUESTS} in {wall:.2f}s "
          f"({N_REQUESTS / wall:.0f} req/s), wrong results: {wrong}")
    print(f"killed worker: w{rep['chaos']['killed_worker']} "
          f"(fault twin: {rep['chaos']['fault_scenario']}), "
          f"failovers: {f['failovers']}, re-admitted: {f['readmitted']}, "
          f"steals: {f['steals']}")
    print(f"survivors: {f['live_workers']}, "
          f"fleet p50/p99: {f['latency_ms']['p50']:.2f}/"
          f"{f['latency_ms']['p99']:.2f} ms\n")
    print("per-worker:")
    for wid, w in sorted(rep["workers"].items()):
        print(f"  w{wid}: state={w['state']:<5} admitted={w['admitted']:<4} "
              f"completed={w['completed']:<4} busy={w['busy_fraction']:.2f}")
    return 1 if wrong else 0


if __name__ == "__main__":
    sys.exit(main())
