"""Distributed sort over a real device mesh (8 simulated devices).

Shows all three methods — 'paper' (equal-width ranges), 'sample'
(balanced splitters), 'hier' (two-level pod-aware exchange) — and the
output contract: shard-balanced globally sorted distribution.

NOTE: sets XLA_FLAGS before importing jax — run as its own process:
    PYTHONPATH=src python examples/distributed_sort_demo.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import dist_sort, host_check_globally_sorted
from repro.data.distributions import make_array


def main():
    n = 1 << 15
    mesh = compat.make_mesh((8,), ("data",))
    mesh2 = compat.make_mesh((2, 4), ("pod", "data"))

    for dist in ("random", "local"):
        x = make_array(dist, n, seed=7)
        for method, m, axes in (
            ("paper", mesh, ("data",)),
            ("sample", mesh, ("data",)),
            ("hier", mesh2, ("pod", "data")),
        ):
            v, c = dist_sort(jnp.asarray(x), mesh=m, axis_names=axes,
                             method=method, capacity_factor=8.0)
            counts = np.asarray(c).ravel()
            ok = host_check_globally_sorted(np.asarray(v), counts)
            shipped = counts.sum()
            imb = counts.max() / max(counts.mean(), 1e-9)
            print(f"{dist:7s} {method:7s} sorted={ok} kept={shipped}/{n} "
                  f"shard imbalance={imb:.2f}"
                  + ("  <- equal-width ranges collapse on clustered values"
                     if method == "paper" and dist == "local" else ""))


if __name__ == "__main__":
    main()
