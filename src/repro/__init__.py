"""repro: OTIS Hyper Hexa-Cell parallel Quick Sort as a multi-pod JAX framework.

Layers: core (the paper's algorithm + distributed sorts), kernels (Pallas
TPU: bitonic sort, bucket partition), models (10 assigned architectures),
configs, data, optim, train, serve, ckpt, runtime (fault tolerance, PP,
collectives), launch (mesh/dryrun/train/serve), roofline.

See DESIGN.md (architecture contract), README.md (map + quickstart), and
benchmarks/README.md (paper figure/table coverage).
"""

__version__ = "1.0.0"
