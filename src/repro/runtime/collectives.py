"""shard_map-level collective tricks for the slow (inter-pod) tier.

The paper's core scheduling insight — do the heavy lifting on the cheap
electrical links, cross the optical tier once — maps to these two
primitives:

* ``hierarchical_psum``: reduce-scatter inside the pod (fast axis), ONE
  all-reduce across pods on the 1/|pod-axis|-sized shard, all-gather
  inside the pod.  Inter-pod bytes drop from full-tensor to
  full-tensor / intra_pod_size.
* ``int8_psum``: QSGD-style quantise → integer psum → dequantise, for
  gradient reductions where 4× fewer bytes beat the quantisation noise
  (pair with error feedback from repro.optim.compression).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat


def int8_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Quantised psum (inside shard_map).  int32 accumulation, f32 scale."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    # every participant must use the SAME scale → max-reduce the scales
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int32)
    summed = jax.lax.psum(q, axis_name)
    return (summed.astype(jnp.float32) * scale).astype(x.dtype)


def hierarchical_psum(x: jax.Array, *, fast_axis: str, slow_axis: str) -> jax.Array:
    """psum over (fast × slow) with minimal slow-axis traffic.

    reduce_scatter(fast) → psum(slow) on the shard → all_gather(fast).
    Equivalent to ``psum(x, (fast, slow))`` but the slow tier carries
    1/|fast| of the bytes — the paper's optical-tier economy.
    """
    n_fast = compat.axis_size(fast_axis)
    lead = x.shape[0]
    if lead % n_fast:
        # fall back for indivisible leading dims
        return jax.lax.psum(x, (fast_axis, slow_axis))
    shard = jax.lax.psum_scatter(x, fast_axis, scatter_dimension=0, tiled=True)
    shard = jax.lax.psum(shard, slow_axis)
    return jax.lax.all_gather(shard, fast_axis, axis=0, tiled=True)
