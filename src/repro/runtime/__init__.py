from repro.runtime.elastic import elastic_mesh, reshard_state
from repro.runtime.collectives import int8_psum, hierarchical_psum

__all__ = ["elastic_mesh", "reshard_state", "int8_psum", "hierarchical_psum"]
