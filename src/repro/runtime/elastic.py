"""Elastic scaling: rebuild the mesh after pod loss, reshard the state.

The contract: checkpoints store *logical* arrays (Checkpointer), so any
surviving device population that can still hold the model restores and
continues.  ``elastic_mesh`` picks the largest (pods', data, model) grid
that fits the live devices; ``reshard_state`` device_puts a restored state
tree onto it with the same PartitionSpec tree (specs are logical — they
survive mesh size changes as long as axis names remain)."""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding


def elastic_mesh(
    target_shape: tuple[int, ...],
    axis_names: tuple[str, ...],
    devices=None,
) -> Mesh:
    """Largest mesh of `axis_names` that fits the available devices, shrinking
    the FIRST axis (pods) first — losing a pod halves the pod axis, never the
    intra-pod topology."""
    devices = list(devices if devices is not None else jax.devices())
    shape = list(target_shape)
    while int(np.prod(shape)) > len(devices) and shape[0] > 1:
        shape[0] -= 1
    if int(np.prod(shape)) > len(devices):
        raise ValueError(
            f"cannot fit mesh {target_shape} (even at pod=1) on {len(devices)} devices"
        )
    use = devices[: int(np.prod(shape))]
    arr = np.array(use).reshape(shape)
    return Mesh(arr, axis_names)


def reshard_state(state, spec_tree, mesh: Mesh):
    """device_put every leaf with its PartitionSpec on the (new) mesh."""
    import jax.numpy as jnp

    def put(x, spec):
        if spec is None:
            return jax.device_put(x, NamedSharding(mesh, jax.sharding.PartitionSpec()))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(
        put, state, spec_tree,
        is_leaf=lambda s: s is None or isinstance(s, jax.sharding.PartitionSpec),
    )
