"""Pipeline parallelism (GPipe) over a 'pipe' mesh axis via shard_map.

The layer stack is split into S stages (stage s owns layers [s·L/S,
(s+1)·L/S)); a microbatched forward streams activations stage-to-stage
with ``ppermute`` (nearest-neighbour — on the paper's topology these are
the cheap electrical hops).  The classic GPipe schedule: with M
microbatches and S stages the bubble fraction is (S−1)/(M+S−1).

Scope: forward-only inference/eval pipeline (the framework's production
training parallelism is FSDP×TP; PP is provided for the assignment's
parallelism-feature coverage and validated numerically on a fake-device
mesh).  Works with any per-layer block fn of signature (params_l, x)→x.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat


def pipeline_forward(
    stacked_params,
    x: jax.Array,  # (M, mb, ...) microbatched input
    block_fn,
    *,
    mesh: Mesh,
    pipe_axis: str = "pipe",
):
    """Run (M, mb, …) microbatches through an L-layer stack split over the
    pipe axis.  Returns (M, mb, …) outputs.

    stacked_params: pytree with leading layer axis L, L % n_stages == 0.
    """
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[pipe_axis]
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    assert L % n_stages == 0, (L, n_stages)
    M = x.shape[0]

    # reshape params to (n_stages, L/S, ...) and shard stage dim over pipe
    per_stage = jax.tree.map(
        lambda a: a.reshape((n_stages, L // n_stages) + a.shape[1:]), stacked_params
    )

    def stage_body(params_stage, xs):
        """One device = one stage.  params_stage: (1, L/S, ...) local."""
        params_stage = jax.tree.map(lambda a: a[0], params_stage)
        stage = jax.lax.axis_index(pipe_axis)
        xs = xs[0]  # (M, mb, ...) replicated input
        n_ticks = M + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def run_stage(h):
            def one(h, p):
                return block_fn(p, h), None

            h, _ = jax.lax.scan(one, h, params_stage)
            return h

        def tick(carry, t):
            buf, out = carry  # buf: (mb,...) activation entering this stage
            # stage s works on microbatch t - s when 0 ≤ t - s < M
            mb_idx = t - stage
            active = (mb_idx >= 0) & (mb_idx < M)
            # stage 0 ingests microbatch t from xs; others use the buffer
            feed = jnp.where(
                stage == 0,
                xs[jnp.clip(t, 0, M - 1)],
                buf,
            )
            y = run_stage(feed)
            y = jnp.where(active, y, buf)
            # last stage emits finished microbatches
            out = jax.lax.cond(
                active & (stage == n_stages - 1),
                lambda o: o.at[jnp.clip(mb_idx, 0, M - 1)].set(y),
                lambda o: o,
                out,
            )
            # stream to the next stage (nearest-neighbour hop)
            nxt = jax.lax.ppermute(y, pipe_axis, perm)
            return (nxt, out), None

        out0 = jnp.zeros_like(xs)
        buf0 = jnp.zeros_like(xs[0])
        (buf, out), _ = jax.lax.scan(
            tick, (buf0, out0), jnp.arange(n_ticks)
        )
        # only the last stage holds real outputs; broadcast them
        out = jax.lax.psum(
            jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out)), pipe_axis
        )
        return out[None]

    fn = compat.shard_map(
        stage_body,
        mesh=mesh,
        in_specs=(P(pipe_axis), P(None)),
        out_specs=P(None),
    )
    # add the leading replicated axis expected by out[None]
    return fn(per_stage, x[None])[0]


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
