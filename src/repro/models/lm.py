"""Decoder-only LMs: dense / MoE / MLA / SSM / hybrid / VLM assembly.

One builder covers seven of the ten assigned architectures.  Layers are
**stacked** and executed with ``lax.scan`` (+ per-layer ``jax.checkpoint``
when ``cfg.remat``), so an 80-layer 110B config lowers to one-layer-sized
HLO.  Per-layer attention *flavour* (window size, rope theta) rides along
the scan as data — traced scalars in the mask/rope math — which keeps the
stack homogeneous even for gemma3's 5:1 local:global pattern.

API (all pure functions):
  init(key, cfg)                       → params
  forward(params, batch, cfg, rules)   → (logits, aux_loss)
  init_cache(cfg, batch, max_len)      → cache pytree
  prefill(params, batch, cfg, rules, cache) → (last_logits, cache)
  decode_step(params, tokens, cfg, rules, cache, pos) → (logits, cache)
  param_specs(cfg, rules, tp_size)     → PartitionSpec pytree (mesh-ready)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.attention import attention
from repro.models.common import (
    AxisRules,
    NO_SHARD,
    dense_init,
    maybe_scan,
    prepend_none_spec,
    shard,
    split_keys,
    stack_layers,
)
from repro.models.rope import apply_mrope, apply_rope


# ============================================================== attention blk
def init_attn(key, cfg: ModelConfig) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = split_keys(key, 4)
    p = {
        "wq": dense_init(k1, (d, H, hd), 0, cfg.param_dtype),
        "wk": dense_init(k2, (d, KV, hd), 0, cfg.param_dtype),
        "wv": dense_init(k3, (d, KV, hd), 0, cfg.param_dtype),
        "wo": dense_init(k4, (H, hd, d), (0, 1), cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), cfg.param_dtype)
        p["bk"] = jnp.zeros((KV, hd), cfg.param_dtype)
        p["bv"] = jnp.zeros((KV, hd), cfg.param_dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.param_dtype)
        p["k_norm"] = jnp.ones((hd,), cfg.param_dtype)
    return p


def attn_specs(cfg) -> dict:
    s = {
        "wq": P("fsdp", "tensor", None),
        "wk": P("fsdp", "tensor", None),
        "wv": P("fsdp", "tensor", None),
        "wo": P("tensor", None, "fsdp"),
    }
    if cfg.qkv_bias:
        s |= {"bq": P("tensor", None), "bk": P("tensor", None), "bv": P("tensor", None)}
    if cfg.qk_norm:
        s |= {"q_norm": P(None), "k_norm": P(None)}
    return s


def _qkv(p, x, cfg, rules, *, positions, theta, positions_thw=None):
    dt = cfg.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = L.rms_norm_head(q, p["q_norm"].astype(jnp.float32))
        k = L.rms_norm_head(k, p["k_norm"].astype(jnp.float32))
    if cfg.mrope_sections and positions_thw is not None:
        q = apply_mrope(q, positions_thw, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions_thw, cfg.mrope_sections, cfg.rope_theta)
    elif cfg.use_rope and positions is not None:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    q = shard(q, rules, "batch", "seq", "heads", None)
    k = shard(k, rules, "batch", "seq", "heads", None)
    v = shard(v, rules, "batch", "seq", "heads", None)
    return q, k, v


def apply_attn_block(
    p, x, cfg, rules, *, positions, window, theta, positions_thw=None,
    cache_kv=None, pos=None,
):
    """Attention sublayer.  Train/prefill when cache_kv is None; returns
    (out, new_kv or (k,v) full-seq for cache building)."""
    q, k, v = _qkv(p, x, cfg, rules, positions=positions, theta=theta,
                   positions_thw=positions_thw)
    if cache_kv is None:
        out = attention(q, k, v, causal=True, window=window, chunk=cfg.attn_chunk,
                        matmul_bf16=cfg.attn_matmul_bf16)
        new_kv = (k, v)
    elif len(cache_kv) == 3:
        # ring-buffer window cache (§Perf lever): O(window) instead of O(seq)
        ck, cv, kpos = cache_kv
        ring = ck.shape[1]
        slot = jax.lax.rem(pos, ring)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), slot, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), slot, 1)
        kpos = jax.lax.dynamic_update_slice_in_dim(
            kpos, pos[None].astype(kpos.dtype) if hasattr(pos, "shape") else
            jnp.asarray([pos], kpos.dtype), slot, 0
        )
        out = attention(
            q, ck, cv, causal=False, window=window, q_offset=pos,
            chunk=cfg.attn_chunk, matmul_bf16=cfg.attn_matmul_bf16,
            k_positions=kpos,
        )
        new_kv = (ck, cv, kpos)
    else:
        ck, cv = cache_kv
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), pos, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), pos, 1)
        out = attention(
            q, ck, cv, causal=False, window=window, q_offset=pos,
            kv_len=pos + 1, chunk=cfg.attn_chunk,
            matmul_bf16=cfg.attn_matmul_bf16,
        )
        new_kv = (ck, cv)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(cfg.dtype))
    return shard(out, rules, "batch", "seq", None), new_kv


# ================================================================ block init
def init_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = split_keys(key, 2)
    if cfg.family == "ssm" or (cfg.is_hybrid):
        return {"ln": L.init_norm(cfg.d_model, cfg), "mamba": SSM.init_mamba(k1, cfg)}
    blk = {"ln1": L.init_norm(cfg.d_model, cfg), "ln2": L.init_norm(cfg.d_model, cfg)}
    if cfg.mla.kv_lora_rank:
        blk["attn"] = MLA.init_mla(k1, cfg)
    else:
        blk["attn"] = init_attn(k1, cfg)
    if cfg.is_moe:
        blk["moe"] = MOE.init_moe(k2, cfg)
    else:
        blk["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg)
    return blk


def block_specs(cfg, tp_size: int) -> dict:
    if cfg.family == "ssm" or cfg.is_hybrid:
        return {"ln": L.norm_specs(cfg), "mamba": SSM.mamba_specs(cfg)}
    s = {"ln1": L.norm_specs(cfg), "ln2": L.norm_specs(cfg)}
    s["attn"] = MLA.mla_specs(cfg) if cfg.mla.kv_lora_rank else attn_specs(cfg)
    s["moe" if cfg.is_moe else "mlp"] = (
        MOE.moe_specs(cfg, tp_size) if cfg.is_moe else L.mlp_specs(cfg)
    )
    return s


def apply_block(
    blk, x, cfg, rules, *, positions, window, theta, aux, positions_thw=None,
    cache=None, pos=None,
):
    """One decoder layer.  Returns (x, aux, new_cache)."""
    if cfg.family == "ssm" or cfg.is_hybrid:
        h = L.apply_norm(blk["ln"], x, cfg)
        y, new_cache = SSM.apply_mamba(blk["mamba"], h, cfg, rules, cache=cache, pos=pos)
        return x + y, aux, new_cache
    h = L.apply_norm(blk["ln1"], x, cfg)
    if cfg.mla.kv_lora_rank:
        if cache is None:
            a, latent = MLA.mla_attention(
                blk["attn"], h, cfg, rules, positions=positions, chunk=cfg.attn_chunk
            )
            new_cache = latent
        else:
            a, new_cache = MLA.mla_decode(blk["attn"], h, cfg, rules, cache=cache, pos=pos)
    else:
        a, new_cache = apply_attn_block(
            blk["attn"], h, cfg, rules, positions=positions, window=window,
            theta=theta, positions_thw=positions_thw, cache_kv=cache, pos=pos,
        )
    x = x + a
    h2 = L.apply_norm(blk["ln2"], x, cfg)
    if cfg.is_moe:
        y, aux_l = MOE.apply_moe(blk["moe"], h2, cfg, rules)
        aux = aux + aux_l
    else:
        y = L.apply_mlp(blk["mlp"], h2, cfg, rules)
    return x + y, aux, new_cache


# ============================================================ shared (zamba2)
def init_shared_block(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = split_keys(key, 3)
    return {
        "in_proj": dense_init(k1, (2 * cfg.d_model, cfg.d_model), 0, cfg.param_dtype),
        "ln1": L.init_norm(cfg.d_model, cfg),
        "attn": init_attn(k2, cfg),
        "ln2": L.init_norm(cfg.d_model, cfg),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg),
    }


def shared_block_specs(cfg) -> dict:
    return {
        "in_proj": P("fsdp", "tensor"),
        "ln1": L.norm_specs(cfg),
        "attn": attn_specs(cfg),
        "ln2": L.norm_specs(cfg),
        "mlp": L.mlp_specs(cfg),
    }


def apply_shared_block(
    p, x, x0, cfg, rules, *, positions, cache=None, pos=None
):
    """Zamba2 shared attention block: concat(h, embeddings) → proj → attn+MLP."""
    cat = jnp.concatenate([x, x0], axis=-1)
    t = jnp.einsum("bse,ed->bsd", cat, p["in_proj"].astype(cfg.dtype))
    h = L.apply_norm(p["ln1"], t, cfg)
    a, new_cache = apply_attn_block(
        p["attn"], h, cfg, rules, positions=positions, window=0,
        theta=cfg.rope_theta, cache_kv=cache, pos=pos,
    )
    t = t + a
    h2 = L.apply_norm(p["ln2"], t, cfg)
    t = t + L.apply_mlp(p["mlp"], h2, cfg, rules)
    return x + t, new_cache


# ==================================================================== init
def init(key, cfg: ModelConfig) -> dict:
    keys = split_keys(key, cfg.num_layers + 3)
    params = {
        "embedding": L.init_embedding(keys[0], cfg),
        "final_norm": L.init_norm(cfg.d_model, cfg),
        "blocks": stack_layers([init_block(keys[2 + i], cfg) for i in range(cfg.num_layers)]),
    }
    if cfg.is_hybrid:
        params["shared"] = init_shared_block(keys[1], cfg)
    return params


def param_specs(cfg: ModelConfig, rules: AxisRules, tp_size: int = 1):
    specs = {
        "embedding": L.embedding_specs(cfg),
        "final_norm": L.norm_specs(cfg),
        "blocks": prepend_none_spec(block_specs(cfg, tp_size)),
    }
    if cfg.is_hybrid:
        specs["shared"] = shared_block_specs(cfg)
    return L.resolve_specs(specs, rules)


def _layer_meta(cfg):
    """Per-layer (window, theta) arrays carried through the scan as data."""
    windows = jnp.array(
        [cfg.layer_window(l) for l in range(cfg.num_layers)], jnp.int32
    )
    tg = cfg.rope_theta_global or cfg.rope_theta
    thetas = jnp.array(
        [
            (tg if cfg.layer_window(l) == 0 else cfg.rope_theta)
            for l in range(cfg.num_layers)
        ],
        jnp.float32,
    )
    return windows, thetas


def _embed_in(params, batch, cfg, rules):
    tokens = batch["tokens"]
    x = L.embed_tokens(params["embedding"], tokens, cfg, rules)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    ve = batch.get("vision_embeds")
    if ve is not None and cfg.vision_tokens:
        x = jax.lax.dynamic_update_slice_in_dim(x, ve.astype(x.dtype), 0, 1)
    return x


# ==================================================================== forward
def forward(params, batch, cfg: ModelConfig, rules: AxisRules = NO_SHARD):
    """Training forward: returns (logits (B,S,V), aux_loss)."""
    x = _embed_in(params, batch, cfg, rules)
    B, S = batch["tokens"].shape
    positions = jnp.arange(S)
    positions_thw = batch.get("positions_thw")
    windows, thetas = _layer_meta(cfg)

    def body(carry, xs):
        x, aux = carry
        blk, w, th = xs
        x, aux, _ = apply_block(
            blk, x, cfg, rules, positions=positions, window=w, theta=th, aux=aux,
            positions_thw=positions_thw,
        )
        return (x, aux), None

    body_fn = jax.checkpoint(body) if cfg.remat else body

    aux0 = jnp.zeros((), jnp.float32)
    if cfg.is_hybrid:
        x0 = x
        period = cfg.hybrid_period
        n_periods = cfg.num_layers // period
        blocks = jax.tree.map(
            lambda a: a.reshape((n_periods, period) + a.shape[1:]), params["blocks"]
        )

        def period_body(carry, xs):
            x, aux = carry
            pblk, w, th = xs

            def inner(c, b):
                return body_fn(c, (b, w[0], th[0]))

            (x, aux), _ = maybe_scan(inner, (x, aux), pblk, cfg.scan_layers)
            x, _ = apply_shared_block(
                params["shared"], x, x0, cfg, rules, positions=positions
            )
            return (x, aux), None

        w2 = windows.reshape(n_periods, period)
        t2 = thetas.reshape(n_periods, period)
        (x, aux), _ = maybe_scan(
            period_body, (x, aux0), (blocks, w2, t2), cfg.scan_layers
        )
    else:
        (x, aux), _ = maybe_scan(
            body_fn, (x, aux0), (params["blocks"], windows, thetas),
            cfg.scan_layers,
        )

    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embedding"], x, cfg, rules)
    return logits, aux


# ================================================================ serve paths
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    """Per-layer cache stacked on a leading L axis (scan-compatible)."""
    dtype = dtype or cfg.dtype
    Lc = cfg.num_layers
    if cfg.family == "ssm":
        one = SSM.init_mamba_cache(cfg, batch, dtype)
        return {"layers": jax.tree.map(lambda a: jnp.broadcast_to(a, (Lc,) + a.shape).copy(), one)}
    if cfg.is_hybrid:
        one = SSM.init_mamba_cache(cfg, batch, dtype)
        n_periods = Lc // cfg.hybrid_period
        KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        return {
            "layers": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (Lc,) + a.shape).copy(), one
            ),
            "shared": (
                jnp.zeros((n_periods, batch, max_len, KV, hd), dtype),
                jnp.zeros((n_periods, batch, max_len, KV, hd), dtype),
            ),
        }
    if cfg.mla.kv_lora_rank:
        one = MLA.init_mla_cache(cfg, batch, max_len, dtype)
        return {"layers": jax.tree.map(lambda a: jnp.broadcast_to(a, (Lc,) + a.shape).copy(), one)}
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.decode_window_cache:
        ws = [cfg.layer_window(l) for l in range(Lc)]
        if not all(w > 0 for w in ws):
            raise ValueError("decode_window_cache needs every layer windowed")
        from repro.models.attention import RING_INVALID

        ring = max(ws)
        ring += (-ring) % 16  # mesh-divisible
        return {
            "layers": (
                jnp.zeros((Lc, batch, ring, KV, hd), dtype),
                jnp.zeros((Lc, batch, ring, KV, hd), dtype),
                jnp.full((Lc, ring), RING_INVALID, jnp.int32),
            )
        }
    return {
        "layers": (
            jnp.zeros((Lc, batch, max_len, KV, hd), dtype),
            jnp.zeros((Lc, batch, max_len, KV, hd), dtype),
        )
    }


def prefill(params, batch, cfg: ModelConfig, rules: AxisRules, cache: dict):
    """Run the prompt through the model, filling the cache.

    Returns (last-position logits (B,V), cache).  Prompt length = tokens.shape[1];
    caches were sized to max_len ≥ prompt + new tokens.
    """
    x = _embed_in(params, batch, cfg, rules)
    B, S = batch["tokens"].shape
    positions = jnp.arange(S)
    positions_thw = batch.get("positions_thw")
    windows, thetas = _layer_meta(cfg)
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.family == "ssm":
        def body(carry, xs):
            x, aux = carry
            blk, w, th, c = xs
            x, aux, nc = apply_block(
                blk, x, cfg, rules, positions=positions, window=w, theta=th,
                aux=aux, cache=c,
            )
            return (x, aux), nc

        (x, _), new_layers = maybe_scan(
            body, (x, aux0), (params["blocks"], windows, thetas, cache["layers"]),
            cfg.scan_layers,
        )
        cache = {"layers": new_layers}
    elif cfg.is_hybrid:
        x0 = x
        period = cfg.hybrid_period
        n_periods = cfg.num_layers // period
        blocks = jax.tree.map(
            lambda a: a.reshape((n_periods, period) + a.shape[1:]), params["blocks"]
        )
        lcache = jax.tree.map(
            lambda a: a.reshape((n_periods, period) + a.shape[1:]), cache["layers"]
        )

        def period_body(carry, xs):
            x, aux = carry
            pblk, w, th, pc, sc = xs

            def inner(c, b_and_cache):
                b, cc = b_and_cache
                x, aux, nc = apply_block(
                    b, c[0], cfg, rules, positions=positions, window=w[0],
                    theta=th[0], aux=c[1], cache=cc,
                )
                return (x, aux), nc

            (x, aux), ncs = maybe_scan(inner, (x, aux), (pblk, pc), cfg.scan_layers)
            # shared attention block fills its per-period KV cache
            ck, cv = sc
            x, (nk, nv) = apply_shared_block(
                params["shared"], x, x0, cfg, rules, positions=positions,
                cache=None, pos=None,
            )
            # write full-seq K/V into padded cache
            nk_, nv_ = nk, nv
            ck = jax.lax.dynamic_update_slice_in_dim(ck, nk_.astype(ck.dtype), 0, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, nv_.astype(cv.dtype), 0, 1)
            return (x, aux), (ncs, (ck, cv))

        w2, t2 = windows.reshape(n_periods, period), thetas.reshape(n_periods, period)
        (x, _), (nlayers, nshared) = maybe_scan(
            period_body, (x, aux0), (blocks, w2, t2, lcache, cache["shared"]),
            cfg.scan_layers,
        )
        cache = {
            "layers": jax.tree.map(
                lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), nlayers
            ),
            "shared": nshared,
        }
    elif cfg.decode_window_cache:
        # ring cache: keep only the last `ring` prompt positions per layer
        def body(carry, xs):
            x, aux = carry
            blk, w, th, (ck, cv, kpos) = xs
            x, aux, kv = apply_block(
                blk, x, cfg, rules, positions=positions, window=w, theta=th,
                aux=aux, positions_thw=positions_thw,
            )
            k_full, v_full = kv
            ring = ck.shape[1]
            S_ = k_full.shape[1]
            if S_ >= ring:
                keep_pos = jnp.arange(S_ - ring, S_)
                slots = keep_pos % ring
                ck = ck.at[:, slots].set(k_full[:, -ring:].astype(ck.dtype))
                cv = cv.at[:, slots].set(v_full[:, -ring:].astype(cv.dtype))
                kpos = kpos.at[slots].set(keep_pos.astype(kpos.dtype))
            else:
                ck = jax.lax.dynamic_update_slice(
                    ck, k_full.astype(ck.dtype), (0, 0, 0, 0)
                )
                cv = jax.lax.dynamic_update_slice(
                    cv, v_full.astype(cv.dtype), (0, 0, 0, 0)
                )
                kpos = jax.lax.dynamic_update_slice(
                    kpos, jnp.arange(S_, dtype=kpos.dtype), (0,)
                )
            return (x, aux), (ck, cv, kpos)

        (x, _), new_layers = maybe_scan(
            body, (x, aux0),
            (params["blocks"], windows, thetas, cache["layers"]),
            cfg.scan_layers,
        )
        cache = {"layers": new_layers}
    elif cfg.prefill_inscan_cache:
        # §Perf lever: write each layer's K/V (or MLA latent) into its padded
        # cache slice INSIDE the scan body — avoids materialising the whole
        # stacked (L,B,S,…) K/V tree a second time before one bulk copy.
        def body(carry, xs):
            x, aux = carry
            blk, w, th, centry = xs
            x, aux, kv = apply_block(
                blk, x, cfg, rules, positions=positions, window=w, theta=th,
                aux=aux, positions_thw=positions_thw,
            )
            if cfg.mla.kv_lora_rank:
                c_new = jax.lax.dynamic_update_slice(
                    centry["c"], kv[0].astype(centry["c"].dtype), (0, 0, 0)
                )
                kr_new = jax.lax.dynamic_update_slice(
                    centry["kr"], kv[1].astype(centry["kr"].dtype), (0, 0, 0)
                )
                return (x, aux), {"c": c_new, "kr": kr_new}
            ck, cv = centry
            ck = jax.lax.dynamic_update_slice(ck, kv[0].astype(ck.dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, kv[1].astype(cv.dtype), (0, 0, 0, 0))
            return (x, aux), (ck, cv)

        (x, _), new_layers = maybe_scan(
            body, (x, aux0),
            (params["blocks"], windows, thetas, cache["layers"]),
            cfg.scan_layers,
        )
        cache = {"layers": new_layers}
    else:
        def body(carry, xs):
            x, aux = carry
            blk, w, th = xs
            x, aux, kv = apply_block(
                blk, x, cfg, rules, positions=positions, window=w, theta=th,
                aux=aux, positions_thw=positions_thw,
            )
            return (x, aux), kv

        (x, _), kvs = maybe_scan(
            body, (x, aux0), (params["blocks"], windows, thetas), cfg.scan_layers
        )
        if cfg.mla.kv_lora_rank:
            c0, kr0 = cache["layers"]["c"], cache["layers"]["kr"]
            c0 = jax.lax.dynamic_update_slice(c0, kvs[0].astype(c0.dtype), (0, 0, 0, 0))
            kr0 = jax.lax.dynamic_update_slice(kr0, kvs[1].astype(kr0.dtype), (0, 0, 0, 0))
            cache = {"layers": {"c": c0, "kr": kr0}}
        else:
            ck, cv = cache["layers"]
            ck = jax.lax.dynamic_update_slice(ck, kvs[0].astype(ck.dtype), (0, 0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, kvs[1].astype(cv.dtype), (0, 0, 0, 0, 0))
            cache = {"layers": (ck, cv)}

    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embedding"], x[:, -1:], cfg, rules)
    return logits[:, 0], cache


def decode_step(params, tokens, cfg: ModelConfig, rules: AxisRules, cache: dict, pos):
    """One token for every sequence.  tokens: (B, 1).  pos: traced scalar."""
    batch = {"tokens": tokens}
    x = _embed_in(params, batch, cfg, rules)
    positions = None  # per-block paths use pos directly
    windows, thetas = _layer_meta(cfg)
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.is_hybrid:
        x0 = x
        period = cfg.hybrid_period
        n_periods = cfg.num_layers // period
        blocks = jax.tree.map(
            lambda a: a.reshape((n_periods, period) + a.shape[1:]), params["blocks"]
        )
        lcache = jax.tree.map(
            lambda a: a.reshape((n_periods, period) + a.shape[1:]), cache["layers"]
        )

        def period_body(carry, xs):
            x = carry
            pblk, pc, sc = xs

            def inner(c, b_and_cache):
                b, cc = b_and_cache
                x, _, nc = apply_block(
                    b, c, cfg, rules, positions=None, window=0, theta=cfg.rope_theta,
                    aux=aux0, cache=cc, pos=pos,
                )
                return x, nc

            x, ncs = maybe_scan(inner, x, (pblk, pc), cfg.scan_layers)
            x, nsc = apply_shared_block(
                params["shared"], x, x0, cfg, rules,
                positions=pos + jnp.zeros((1,), jnp.int32), cache=sc, pos=pos,
            )
            return x, (ncs, nsc)

        x, (nlayers, nshared) = maybe_scan(
            period_body, x, (blocks, lcache, cache["shared"]), cfg.scan_layers
        )
        cache = {
            "layers": jax.tree.map(
                lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), nlayers
            ),
            "shared": nshared,
        }
    else:
        positions = pos + jnp.zeros((1,), jnp.int32)
        positions_thw = None
        if cfg.mrope_sections:
            positions_thw = jnp.broadcast_to(
                pos, (3, tokens.shape[0], 1)
            ).astype(jnp.int32)

        def body(x, xs):
            blk, w, th, c = xs
            x, _, nc = apply_block(
                blk, x, cfg, rules, positions=positions, window=w, theta=th,
                aux=aux0, positions_thw=positions_thw, cache=c, pos=pos,
            )
            return x, nc

        x, new_layers = maybe_scan(
            body, x, (params["blocks"], windows, thetas, cache["layers"]),
            cfg.scan_layers,
        )
        cache = {"layers": new_layers}

    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embedding"], x, cfg, rules)
    return logits[:, 0], cache
