"""Mixture-of-Experts with sort-based dispatch — the paper's technique
as a first-class framework feature.

Expert routing *is* the Array Division Procedure (§3.1) with
``SubDivider = 1``: each (token, expert-choice) assignment is an element
whose "value" is its expert id; bucketing assignments by expert id and
laying each bucket out contiguously is exactly the paper's value-range
partition, and the merge-free gather property becomes the contiguous
(expert, capacity) buffer the grouped FFN matmul wants.

``dispatch='sorted'`` uses ``repro.core.partition`` bucket counts/ranks
(the same math as the Pallas ``partition_kernel``) to compute, for every
assignment, its slot in the (E, C, d) dispatch buffer — histogram + stable
rank, no data-dependent control flow.  ``dispatch='argsort'`` computes the
same ranks from ONE stable argsort of the expert ids (position minus
group start) — the ``SortEngine.sort_pairs`` permutation-gather
formulation in-graph, O(A log A) instead of the one-hot O(A·E), with
bit-identical outputs (DESIGN.md §12; the before/after lives in
``benchmarks/bench_workloads.py``).  ``dispatch='dense'`` is the one-hot
einsum baseline (tiny shapes / numerics oracle).

Sharding: expert-parallel (experts → tensor axis) when ``E % tp == 0``,
else tensor-parallel on d_ff.  On the multi-pod mesh the (E,C,d) buffer's
token dim additionally shards over the batch axes, giving the hierarchical
"cross the pod axis once" exchange when XLA partitions the gather/scatter.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import partition as core_partition
from repro.models.common import AxisRules, dense_init, shard, split_keys


def init_moe(key, cfg) -> dict:
    m = cfg.moe
    d = cfg.d_model
    keys = split_keys(key, 7)
    p = {
        "router": dense_init(keys[0], (d, m.num_experts), 0, cfg.param_dtype),
        "wi": dense_init(keys[1], (m.num_experts, d, m.expert_d_ff), 1, cfg.param_dtype),
        "wg": dense_init(keys[2], (m.num_experts, d, m.expert_d_ff), 1, cfg.param_dtype),
        "wo": dense_init(keys[3], (m.num_experts, m.expert_d_ff, d), 1, cfg.param_dtype),
    }
    if m.num_shared_experts:
        ff = m.shared_d_ff * m.num_shared_experts
        p["shared_wi"] = dense_init(keys[4], (d, ff), 0, cfg.param_dtype)
        p["shared_wg"] = dense_init(keys[5], (d, ff), 0, cfg.param_dtype)
        p["shared_wo"] = dense_init(keys[6], (ff, d), 0, cfg.param_dtype)
    return p


def moe_specs(cfg, tp_size: int) -> dict:
    m = cfg.moe
    ep = m.num_experts % max(tp_size, 1) == 0 and tp_size > 1
    if ep:
        e_wi = P("tensor", "fsdp", None)
        e_wo = P("tensor", None, "fsdp")
    else:
        e_wi = P(None, "fsdp", "tensor")
        e_wo = P(None, "tensor", "fsdp")
    s = {"router": P("fsdp", None), "wi": e_wi, "wg": e_wi, "wo": e_wo}
    if m.num_shared_experts:
        s["shared_wi"] = P("fsdp", "tensor")
        s["shared_wg"] = P("fsdp", "tensor")
        s["shared_wo"] = P("tensor", "fsdp")
    return s


def _router(p, x, cfg):
    """Top-k routing: probs, expert ids, aux load-balance loss."""
    m = cfg.moe
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(cfg.dtype)).astype(
        jnp.float32
    )
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.num_experts_per_tok)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    # Switch-style aux loss: E · Σ_e f_e · P_e
    token_frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, m.num_experts, dtype=jnp.float32), axis=2),
        axis=(0, 1),
    ) / m.num_experts_per_tok
    prob_frac = jnp.mean(probs, axis=(0, 1))
    aux = m.num_experts * jnp.sum(token_frac * prob_frac) * m.router_aux_loss
    return top_p, top_e, aux


def _expert_ffn(p, xs, cfg):
    """Grouped FFN over the (E, C, d) dispatch buffer."""
    dt = cfg.dtype
    h = jnp.einsum("ecd,edf->ecf", xs, p["wi"].astype(dt))
    g = jnp.einsum("ecd,edf->ecf", xs, p["wg"].astype(dt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"].astype(dt))


def _moe_shard_map(p, x, cfg, rules, top_p, top_e):
    """shard_map dispatch (§Perf lever, dispatch='shard_map').

    The pjit scatter/gather dispatch replicates the (E,C,d) buffer and
    all-reduces it (SPMD scatter with data-dependent indices can't be
    partitioned).  Here tokens NEVER leave their device: each TP rank
    holds a d_ff-slice of every expert, builds its bucket buffer from
    LOCAL tokens only (the Array Division Procedure runs per shard),
    computes partial expert outputs, combines locally, and one psum over
    the TP axis finishes the job.  Inter-pod traffic: ZERO (tokens stay
    pod-local) — the paper's "cross the optical tier once" ideal, beaten:
    the optical tier isn't crossed at all.
    """
    m = cfg.moe
    mesh = compat.get_ambient_mesh()
    if mesh is None or not mesh.shape or rules.tensor not in mesh.shape:
        # no mesh context (CPU tests): same math, local
        return None
    B, S, d = x.shape
    k = m.num_experts_per_tok
    batch_axes = rules.batch or ()
    bsz = 1
    for a in batch_axes:
        bsz *= mesh.shape[a]
    if B % max(bsz, 1):
        return None
    T_loc = (B // max(bsz, 1)) * S
    cap = int(-(-T_loc * k * m.capacity_factor // m.num_experts))
    cap += (-cap) % 8
    tensor_ax = rules.tensor

    def local(x_loc, tp_loc, te_loc, wi, wg, wo):
        Bl, Sl, _ = x_loc.shape
        T = Bl * Sl
        flat_e = te_loc.reshape(T * k)
        flat_w = tp_loc.reshape(T * k).astype(jnp.float32)
        tok_idx = jnp.repeat(jnp.arange(T), k)
        ranks = core_partition.bucket_ranks(flat_e, m.num_experts)
        keep = ranks < cap
        slot = jnp.where(keep, flat_e * cap + ranks, m.num_experts * cap)
        xt = x_loc.reshape(T, d)
        buf = jnp.zeros((m.num_experts * cap + 1, d), cfg.dtype)
        buf = buf.at[slot].set(xt[tok_idx])[:-1].reshape(m.num_experts, cap, d)
        dt = cfg.dtype
        h = jnp.einsum("ecd,edf->ecf", buf, wi.astype(dt))
        g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt))
        part = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo.astype(dt))
        part = part.reshape(m.num_experts * cap, d)
        contrib = jnp.concatenate([part, jnp.zeros((1, d), part.dtype)])[
            jnp.where(keep, slot, m.num_experts * cap)
        ]
        y = jnp.zeros((T, d), jnp.float32)
        y = y.at[tok_idx].add(contrib.astype(jnp.float32) * flat_w[:, None])
        # d_ff is sliced over the TP axis → partial sums; one psum finishes
        y = jax.lax.psum(y, tensor_ax)
        return y.reshape(Bl, Sl, d).astype(cfg.dtype)

    from jax.sharding import PartitionSpec as PS

    bspec = PS(batch_axes or None, None, None)
    out = compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(
            bspec,
            bspec,
            bspec,
            PS(None, None, tensor_ax),
            PS(None, None, tensor_ax),
            PS(None, tensor_ax, None),
        ),
        out_specs=bspec,
    )(x, top_p, top_e, p["wi"], p["wg"], p["wo"])
    return out


def apply_moe(p, x, cfg, rules: AxisRules):
    """Returns (y, aux_loss).  x: (B, S, d)."""
    m = cfg.moe
    B, S, d = x.shape
    top_p, top_e, aux = _router(p, x, cfg)

    if m.dispatch == "dense":
        # oracle path: every expert runs on every token
        one_hot = jax.nn.one_hot(top_e, m.num_experts, dtype=jnp.float32)
        gates = jnp.sum(one_hot * top_p[..., None], axis=2)  # (B,S,E)
        h = jnp.einsum("bsd,edf->bsef", x, p["wi"].astype(cfg.dtype))
        g = jnp.einsum("bsd,edf->bsef", x, p["wg"].astype(cfg.dtype))
        y = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g) * h, p["wo"].astype(cfg.dtype))
        y = jnp.einsum("bsed,bse->bsd", y.astype(jnp.float32), gates).astype(cfg.dtype)
    elif m.dispatch == "shard_map":
        y = _moe_shard_map(p, x, cfg, rules, top_p, top_e)
        if y is None:  # no mesh (CPU tests) → same math via the pjit path
            cfg2 = cfg.replace(moe=cfg.moe.__class__(
                **{**cfg.moe.__dict__, "dispatch": "sorted"}))
            return apply_moe(p, x, cfg2, rules)  # incl. shared experts
    elif m.dispatch in ("sorted", "argsort"):
        T = B * S
        k = m.num_experts_per_tok
        A = T * k  # total assignments
        cap = int(-(-A * m.capacity_factor // m.num_experts))
        cap += (-cap) % 8
        flat_e = top_e.reshape(A)  # assignment → expert id ("value" to bucket)
        flat_w = top_p.reshape(A).astype(jnp.float32)
        tok_idx = jnp.repeat(jnp.arange(T), k)
        counts = core_partition.bucket_counts(flat_e, m.num_experts)
        if m.dispatch == "argsort":
            # --- sort_pairs formulation: ONE stable argsort groups the
            # assignments by expert, and each rank is its position minus
            # its expert's group start — O(A log A) against 'sorted''s
            # O(A·E) one-hot rank matrix, the in-graph twin of
            # ``SortEngine.sort_pairs``' permutation gather (DESIGN.md
            # §12).  jnp.argsort is stable, so ranks keep order-of-
            # appearance and the outputs are bit-identical to 'sorted'.
            order = jnp.argsort(flat_e)
            starts = jnp.cumsum(counts) - counts
            ranks_sorted = (
                jnp.arange(A, dtype=jnp.int32) - starts[flat_e[order]]
            )
            ranks = jnp.zeros(A, jnp.int32).at[order].set(ranks_sorted)
        else:
            # --- Array Division: histogram + stable rank per bucket -----
            ranks = core_partition.bucket_ranks(flat_e, m.num_experts)
        keep = ranks < cap
        slot = jnp.where(keep, flat_e * cap + ranks, m.num_experts * cap)
        # dispatch buffer (E*C, d): gather token vectors into bucket order
        xt = x.reshape(T, d)
        buf = jnp.zeros((m.num_experts * cap + 1, d), cfg.dtype)
        buf = buf.at[slot].set(xt[tok_idx])[:-1]
        buf = buf.reshape(m.num_experts, cap, d)
        if m.dispatch_sharded:
            e_ax = "tensor" if m.expert_parallel else None
            buf = shard(buf, rules, e_ax, "batch", None)
            ye = _expert_ffn(p, buf, cfg)
            ye = shard(ye, rules, e_ax, "batch", None).reshape(
                m.num_experts * cap, d
            )
        else:
            buf = shard(buf, rules, "tensor", None, None)
            ye = _expert_ffn(p, buf, cfg).reshape(m.num_experts * cap, d)
        # combine: weighted scatter-add back to tokens
        contrib = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)])[
            jnp.where(keep, slot, m.num_experts * cap)
        ]
        y = jnp.zeros((T, d), jnp.float32)
        y = y.at[tok_idx].add(contrib.astype(jnp.float32) * flat_w[:, None])
        y = y.reshape(B, S, d).astype(cfg.dtype)
        del counts
    else:
        raise ValueError(f"unknown dispatch {m.dispatch!r}")

    if m.num_shared_experts:
        dt = cfg.dtype
        h = jnp.einsum("bsd,df->bsf", x, p["shared_wi"].astype(dt))
        g = jnp.einsum("bsd,df->bsf", x, p["shared_wg"].astype(dt))
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h, p["shared_wo"].astype(dt))
    y = shard(y, rules, "batch", "seq", None)
    return y, aux
