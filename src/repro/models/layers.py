"""Norms, MLPs, embeddings — the boring substrate, done properly."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import AxisRules, dense_init, shard, split_keys


# ------------------------------------------------------------------- norms
def init_norm(d: int, cfg) -> dict:
    p = {"scale": jnp.ones((d,), cfg.param_dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), cfg.param_dtype)
    return p


def apply_norm(p: dict, x: jax.Array, cfg) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-6) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"]
    return out.astype(x.dtype)


def norm_specs(cfg) -> dict:
    s = {"scale": P(None)}
    if cfg.norm == "layernorm":
        s["bias"] = P(None)
    return s


def rms_norm_head(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Per-head QK-norm (gemma3): RMS over head_dim."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + 1e-6) * scale).astype(x.dtype)


# -------------------------------------------------------------------- MLP
def init_mlp(key, d: int, d_ff: int, cfg) -> dict:
    if cfg.act == "silu":  # SwiGLU
        k1, k2, k3 = split_keys(key, 3)
        return {
            "wi": dense_init(k1, (d, d_ff), 0, cfg.param_dtype),
            "wg": dense_init(k2, (d, d_ff), 0, cfg.param_dtype),
            "wo": dense_init(k3, (d_ff, d), 0, cfg.param_dtype),
        }
    k1, k2 = split_keys(key, 2)
    return {
        "wi": dense_init(k1, (d, d_ff), 0, cfg.param_dtype),
        "wo": dense_init(k2, (d_ff, d), 0, cfg.param_dtype),
        "bi": jnp.zeros((d_ff,), cfg.param_dtype),
        "bo": jnp.zeros((d,), cfg.param_dtype),
    }


def apply_mlp(p: dict, x: jax.Array, cfg, rules: AxisRules) -> jax.Array:
    dt = cfg.dtype
    if cfg.act == "silu":
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt))
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt))
        h = jax.nn.silu(g) * h
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt)) + p["bi"].astype(dt)
        h = jax.nn.gelu(h)
    h = shard(h, rules, "batch", "seq", "tensor")
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt))
    if cfg.act != "silu":
        out = out + p["bo"].astype(dt)
    return out


def mlp_specs(cfg) -> dict:
    if cfg.act == "silu":
        return {
            "wi": P("fsdp", "tensor"),
            "wg": P("fsdp", "tensor"),
            "wo": P("tensor", "fsdp"),
        }
    return {
        "wi": P("fsdp", "tensor"),
        "wo": P("tensor", "fsdp"),
        "bi": P("tensor"),
        "bo": P(None),
    }


# -------------------------------------------------------------- embeddings
def init_embedding(key, cfg) -> dict:
    k1, k2 = split_keys(key, 2)
    p = {"embed": dense_init(k1, (cfg.vocab_size, cfg.d_model), 1, cfg.param_dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), 0, cfg.param_dtype)
    return p


def embed_tokens(p: dict, tokens: jax.Array, cfg, rules: AxisRules) -> jax.Array:
    x = jnp.take(p["embed"].astype(cfg.dtype), tokens, axis=0)
    return shard(x, rules, "batch", "seq", None)


def unembed(p: dict, x: jax.Array, cfg, rules: AxisRules) -> jax.Array:
    w = p.get("unembed")
    if w is None:
        w = p["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(cfg.dtype))
    return shard(logits, rules, "batch", "seq", "tensor")


def embedding_specs(cfg) -> dict:
    s = {"embed": P("tensor", "fsdp")}
    if not cfg.tie_embeddings:
        s["unembed"] = P("fsdp", "tensor")
    return s


def resolve_specs(tree, rules: AxisRules):
    """Map logical-name PartitionSpecs → mesh-axis PartitionSpecs."""
    def fix(s):
        if not isinstance(s, P):
            return s
        return rules.spec(*s)

    return jax.tree.map(fix, tree, is_leaf=lambda s: isinstance(s, P))
