"""Attention: GQA, per-layer windows (traced), KV-chunked online softmax.

One implementation serves every attention flavour in the assigned archs:

* full causal (qwen1.5, minitron, deepseek-q/k path, whisper decoder)
* sliding window via a **traced** per-layer window scalar (mixtral SWA,
  gemma3 5:1 local:global — a window of 0 means global), which lets the
  layer stack stay homogeneous under ``lax.scan``
* non-causal (whisper encoder) and cross attention (whisper decoder)
* decode against a padded KV cache with a validity length

Memory: scores are materialised per **KV chunk** only (``lax.scan`` with a
running (max, sum, acc) online softmax — the flash-attention recurrence in
pure JAX).  A 32k-token prefill therefore costs O(S · chunk) scores, not
O(S²), and the scanned HLO stays one-chunk sized for the dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


RING_INVALID = -(1 << 30)  # kpos sentinel for never-written ring slots


def _chunk_mask(
    q_pos: jax.Array,  # (Sq,)
    k_pos: jax.Array,  # (Ck,)
    *,
    causal: bool,
    window,  # traced int32 or python int; 0/None → no window
    kv_len=None,  # traced valid cache length (decode) or None
):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        # w == 0 → global; else keys within the last w positions
        win_ok = (q_pos[:, None] - k_pos[None, :]) < w
        m &= jnp.where(w > 0, win_ok, True)
    if kv_len is not None:
        m &= k_pos[None, :] < kv_len
    return m


def attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, KVH, hd)
    v: jax.Array,  # (B, Sk, KVH, hd_v)
    *,
    causal: bool = True,
    window=None,
    q_offset=0,  # traced or static start position of q within the sequence
    kv_len=None,
    chunk: int = 1024,
    scale: float | None = None,
    matmul_bf16: bool = False,
    k_positions: jax.Array | None = None,  # explicit key positions (ring cache)
) -> jax.Array:
    """Online-softmax attention, GQA via head grouping.  Returns (B,Sq,H,hd_v).

    ``matmul_bf16`` (§Perf lever): QKᵀ and P·V run in bf16 with f32
    accumulation (MXU-native, half the operand traffic); softmax statistics
    stay f32.  Baseline (False) is all-f32 — the numerics oracle.
    """
    B, Sq, H, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = scale if scale is not None else hd ** -0.5
    qf = (q * scale).astype(jnp.float32).reshape(B, Sq, KVH, G, hd)
    q_mm = qf.astype(jnp.bfloat16) if matmul_bf16 else qf
    q_pos = q_offset + jnp.arange(Sq)

    chunk = min(chunk, Sk)
    n_chunks = Sk // chunk
    rem = Sk - n_chunks * chunk

    def body(carry, inputs):
        m_run, l_run, acc = carry
        kc, vc, start = inputs  # (B,C,KVH,hd), (B,C,KVH,hdv), ()
        if k_positions is not None:
            k_pos = jax.lax.dynamic_slice_in_dim(k_positions, start, kc.shape[1])
        else:
            k_pos = start + jnp.arange(kc.shape[1])
        k_mm = kc.astype(jnp.bfloat16) if matmul_bf16 else kc.astype(jnp.float32)
        s = jnp.einsum(
            "bqkgh,bckh->bqkgc", q_mm, k_mm, preferred_element_type=jnp.float32
        )  # (B,Sq,KVH,G,C) f32
        mask = _chunk_mask(q_pos, k_pos, causal=causal, window=window, kv_len=kv_len)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        p_mm = p.astype(jnp.bfloat16) if matmul_bf16 else p
        v_mm = vc.astype(jnp.bfloat16) if matmul_bf16 else vc.astype(jnp.float32)
        pv = jnp.einsum(
            "bqkgc,bckh->bqkgh", p_mm, v_mm, preferred_element_type=jnp.float32
        )
        acc = acc * corr[..., None] + pv
        return (m_new, l_new, acc), None

    hd_v = v.shape[-1]
    init = (
        jnp.full((B, Sq, KVH, G), NEG_INF, jnp.float32),
        jnp.zeros((B, Sq, KVH, G), jnp.float32),
        jnp.zeros((B, Sq, KVH, G, hd_v), jnp.float32),
    )
    if n_chunks > 0:
        ks = k[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, KVH, hd)
        vs = v[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, KVH, hd_v)
        starts = jnp.arange(n_chunks) * chunk
        (m_run, l_run, acc), _ = jax.lax.scan(
            body,
            init,
            (ks.transpose(1, 0, 2, 3, 4), vs.transpose(1, 0, 2, 3, 4), starts),
        )
        init = (m_run, l_run, acc)
    if rem:
        init, _ = body(
            init, (k[:, n_chunks * chunk :], v[:, n_chunks * chunk :], n_chunks * chunk)
        )
    m_run, l_run, acc = init
    out = acc / jnp.maximum(l_run[..., None], 1e-30)
    return out.reshape(B, Sq, H, hd_v).astype(q.dtype)


def attention_with_lse(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    kv_len=None,
    scale: float | None = None,
):
    """Decode attention returning (out, lse) for cross-shard combination.

    Used by the sequence-sharded long-context decode: each shard attends to
    its KV slice; partial results merge with the standard logsumexp rule:
    out = Σ exp(lse_i − lse*)·out_i / Σ exp(lse_i − lse*).
    """
    B, Sq, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    scale = scale if scale is not None else hd ** -0.5
    qf = (q * scale).astype(jnp.float32).reshape(B, Sq, KVH, G, hd)
    k_pos = jnp.arange(k.shape[1])
    s = jnp.einsum("bqkgh,bckh->bqkgc", qf, k.astype(jnp.float32))
    if kv_len is not None:
        s = jnp.where(k_pos[None, None, None, None, :] < kv_len, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bqkgc,bckh->bqkgh", p, v.astype(jnp.float32))
    out = out / jnp.maximum(l[..., None], 1e-30)
    lse = m[..., 0] + jnp.log(jnp.maximum(l, 1e-30))
    return out.reshape(B, Sq, H, v.shape[-1]), lse.reshape(B, Sq, H)
