"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(angles)[..., None, :], jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions_thw: jax.Array,
    sections: tuple[int, ...],
    theta: float = 1000000.0,
) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    ``positions_thw``: (3, B, S) temporal/height/width position ids (text
    tokens have t == h == w).  ``sections`` splits the hd/2 frequency bands
    among the three axes (e.g. (16, 24, 24) for hd=128).
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    # per-frequency-band axis selector: band i uses positions_thw[sel[i]]
    sel = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )  # (hd/2,)
    pos = positions_thw.astype(jnp.float32)[sel]  # (hd/2, B, S)
    pos = jnp.moveaxis(pos, 0, -1)  # (B, S, hd/2)
    angles = pos * freqs  # (B, S, hd/2)
    cos, sin = jnp.cos(angles)[..., None, :], jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (B-broadcastable)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)
