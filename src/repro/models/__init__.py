"""Model substrate: dense/MoE/MLA/SSM/hybrid/enc-dec/VLM in pure JAX.

Every family exposes ``init / forward / prefill / decode / param_specs``
through the builders in ``repro.models.lm`` (decoder LMs incl. MoE, MLA,
SSM, hybrid, VLM) and ``repro.models.encdec`` (whisper).
"""
