"""DeepSeek-V2 Multi-head Latent Attention (MLA).

KV state is compressed into a per-token latent ``c = x·W_dkv`` of rank
``kv_lora_rank`` (512) plus one shared RoPE key ``k_r`` (64) — the decode
cache stores only (c, k_r): 576 dims/token instead of
2·H·hd = 4096 for the equivalent MHA, a 7.1× cache shrink.

Two decode paths:
* expanded (baseline, paper-faithful to DeepSeek): reconstruct per-head
  k_nope = c·W_uk and v = c·W_uv for all cached positions each step;
* absorbed (``cfg.mla.absorb``, beyond-paper optimisation): fold W_uk into
  the query (q̃ = q_nope·W_ukᵀ) and attend directly over the latent, fold
  W_uv into the output — turns decode attention from O(S·H·(dn+dv)·r)
  reconstruction into O(S·H·r) latent dot products.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.attention import attention, attention_with_lse
from repro.models.common import AxisRules, dense_init, shard, split_keys
from repro.models.rope import apply_rope


def init_mla(key, cfg) -> dict:
    a = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    r, dn, dr, dv = a.kv_lora_rank, a.qk_nope_head_dim, a.qk_rope_head_dim, a.v_head_dim
    k1, k2, k3, k4, k5, k6 = split_keys(key, 6)
    return {
        "wq": dense_init(k1, (d, H, dn + dr), 0, cfg.param_dtype),
        "wdkv": dense_init(k2, (d, r), 0, cfg.param_dtype),
        "wkr": dense_init(k3, (d, dr), 0, cfg.param_dtype),
        "wuk": dense_init(k4, (r, H, dn), 0, cfg.param_dtype),
        "wuv": dense_init(k5, (r, H, dv), 0, cfg.param_dtype),
        "wo": dense_init(k6, (H, dv, d), (0, 1), cfg.param_dtype),
    }


def mla_specs(cfg) -> dict:
    return {
        "wq": P("fsdp", "tensor", None),
        "wdkv": P("fsdp", None),
        "wkr": P("fsdp", None),
        "wuk": P(None, "tensor", None),
        "wuv": P(None, "tensor", None),
        "wo": P("tensor", None, "fsdp"),
    }


def _project_q(p, x, cfg, positions):
    a = cfg.mla
    dn = a.qk_nope_head_dim
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(cfg.dtype))
    qn, qr = q[..., :dn], q[..., dn:]
    qr = apply_rope(qr, positions, cfg.rope_theta)
    return qn, qr


def _latent(p, x, cfg, positions):
    c = jnp.einsum("bsd,dr->bsr", x, p["wdkv"].astype(cfg.dtype))
    kr = jnp.einsum("bsd,de->bse", x, p["wkr"].astype(cfg.dtype))
    kr = apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c, kr


def mla_attention(p, x, cfg, rules: AxisRules, *, positions, chunk=1024):
    """Training/prefill forward.  Returns (out, (c, kr)) — latent for caching."""
    a = cfg.mla
    H = cfg.num_heads
    qn, qr = _project_q(p, x, cfg, positions)
    c, kr = _latent(p, x, cfg, positions)
    kn = jnp.einsum("bsr,rhe->bshe", c, p["wuk"].astype(cfg.dtype))
    v = jnp.einsum("bsr,rhe->bshe", c, p["wuv"].astype(cfg.dtype))
    k = jnp.concatenate([kn, jnp.broadcast_to(kr[:, :, None], qr.shape[:2] + (H, a.qk_rope_head_dim))], -1)
    q = jnp.concatenate([qn, qr], -1)
    q = shard(q, rules, "batch", "seq", "heads", None)
    k = shard(k, rules, "batch", "seq", "heads", None)
    scale = (a.qk_nope_head_dim + a.qk_rope_head_dim) ** -0.5
    out = attention(q, k, v, causal=True, chunk=chunk, scale=scale,
                    matmul_bf16=cfg.attn_matmul_bf16)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(cfg.dtype))
    return shard(out, rules, "batch", "seq", None), (c, kr)


def mla_decode(p, x, cfg, rules: AxisRules, *, cache, pos):
    """One decode step against the latent cache.

    cache = {'c': (B, Smax, r), 'kr': (B, Smax, dr)}; pos: traced step.
    """
    a = cfg.mla
    H = cfg.num_heads
    positions = pos + jnp.zeros((1,), jnp.int32)
    qn, qr = _project_q(p, x, cfg, positions)  # (B,1,H,·)
    c_t, kr_t = _latent(p, x, cfg, positions)
    c = jax.lax.dynamic_update_slice_in_dim(cache["c"], c_t.astype(cache["c"].dtype), pos, 1)
    kr = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr_t.astype(cache["kr"].dtype), pos, 1)
    kv_len = pos + 1
    scale = (a.qk_nope_head_dim + a.qk_rope_head_dim) ** -0.5
    if a.absorb:
        # q̃ = qn·W_ukᵀ → attend in latent space; values are the latent too.
        q_lat = jnp.einsum("bshe,rhe->bshr", qn, p["wuk"].astype(cfg.dtype))
        # scores: q̃·c + qr·kr ; one attention over the concatenated dims
        q_cat = jnp.concatenate([q_lat, qr], -1)  # (B,1,H, r+dr)
        k_cat = jnp.concatenate([c, kr], -1)[:, :, None, :]  # (B,S,1, r+dr)
        o_lat, _ = attention_with_lse(
            q_cat, k_cat, c[:, :, None, :], kv_len=kv_len, scale=scale
        )
        o = jnp.einsum("bshr,rhe->bshe", o_lat, p["wuv"].astype(cfg.dtype))
    else:
        kn = jnp.einsum("bsr,rhe->bshe", c, p["wuk"].astype(cfg.dtype))
        v = jnp.einsum("bsr,rhe->bshe", c, p["wuv"].astype(cfg.dtype))
        k = jnp.concatenate(
            [kn, jnp.broadcast_to(kr[:, :, None], kn.shape[:2] + (H, a.qk_rope_head_dim))], -1
        )
        q = jnp.concatenate([qn, qr], -1)
        o = attention(q, k, v, causal=False, kv_len=kv_len, scale=scale,
                      matmul_bf16=cfg.attn_matmul_bf16)
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(cfg.dtype))
    return shard(out, rules, "batch", "seq", None), {"c": c, "kr": kr}


def init_mla_cache(cfg, batch: int, max_len: int, dtype) -> dict:
    a = cfg.mla
    return {
        "c": jnp.zeros((batch, max_len, a.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, max_len, a.qk_rope_head_dim), dtype),
    }
