"""Shared model utilities: init, sharding rules, scan/stack helpers."""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Logical→mesh axis mapping.  ``None`` fields disable that sharding.

    batch:  activation batch dim (tuple of mesh axes, e.g. ('pod','data'))
    fsdp:   weight shard axis (ZeRO-3 style)
    tensor: tensor-parallel axis (heads / ffn / experts / vocab)
    heads:  attention-head activation axis (defaults to tensor; set None and
            set ``seq`` instead for sequence parallelism when head counts
            don't divide the TP axis — e.g. qwen1.5-32b's 40 heads on TP16)
    seq:    sequence activation axis (SP / context parallelism)
    kv_seq: KV-cache sequence axis (long_500k: shard the 500k cache
            over the data axis when batch=1 can't use it)
    """

    batch: tuple[str, ...] | None = ("pod", "data")
    fsdp: str | None = "data"
    tensor: str | None = "model"
    heads: "str | None | object" = "_default"
    seq: str | None = None
    kv_seq: str | None = None
    enabled: bool = True

    def spec(self, *axes) -> P:
        """PartitionSpec from logical names:
        'batch'|'fsdp'|'tensor'|'heads'|'seq'|'kv_seq'|None|raw-mesh-axis."""
        out = []
        for a in axes:
            if a == "batch":
                out.append(self.batch)
            elif a == "fsdp":
                out.append(self.fsdp)
            elif a == "tensor":
                out.append(self.tensor)
            elif a == "heads":
                out.append(self.tensor if self.heads == "_default" else self.heads)
            elif a == "seq":
                out.append(self.seq)
            elif a == "kv_seq":
                out.append(self.kv_seq)
            elif a is None:
                out.append(None)
            else:  # raw mesh axis name passthrough
                out.append(a)
        # a mesh axis may appear at most once per spec: first occurrence
        # wins (SP mode maps seq→model, so tensor entries later in the same
        # spec must drop to replicated).
        seen: set = set()
        dedup = []
        for e in out:
            names = (e,) if isinstance(e, str) else tuple(e or ())
            if any(n in seen for n in names):
                dedup.append(None)
            else:
                seen.update(names)
                dedup.append(e)
        return P(*dedup)


NO_SHARD = AxisRules(batch=None, fsdp=None, tensor=None, enabled=False)


def shard(x: jax.Array, rules: AxisRules, *axes) -> jax.Array:
    """with_sharding_constraint if rules are enabled, else identity."""
    if not rules.enabled:
        return x
    return jax.lax.with_sharding_constraint(x, rules.spec(*axes))


# ----------------------------------------------------------------- init
def dense_init(key, shape: Sequence[int], in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (the boring, correct default)."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else math.prod(
        shape[a] for a in in_axis
    )
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(
        dtype
    )


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# ----------------------------------------------------------------- scan utils
def stack_layers(layer_params: list):
    """Stack a list of identical pytrees along a new leading (layer) axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layer_params)


def maybe_scan(body, init, xs, use_scan: bool = True):
    """lax.scan, or an unrolled python loop over the leading axis.

    The unrolled form exists for the dry-run's cost calibration: XLA's
    cost_analysis counts a while-loop body ONCE regardless of trip count,
    so per-layer FLOPs/bytes/collective traffic are extracted from small
    *unrolled* lowers and scaled (launch/dryrun.py)."""
    if use_scan:
        return jax.lax.scan(body, init, xs)
    carry = init
    ys = []
    n = jax.tree.leaves(xs)[0].shape[0]
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def prepend_none_spec(specs):
    """Layer-stacked params get an unsharded leading axis."""
    return jax.tree.map(
        lambda s: P(*((None,) + tuple(s))) if isinstance(s, P) else s,
        specs,
        is_leaf=lambda s: isinstance(s, P),
    )
