"""Whisper-style encoder-decoder backbone (conv frontend stubbed per spec).

Encoder: precomputed frame embeddings (B, F, d) from ``input_specs`` +
sinusoidal positions → non-causal self-attention stack (LayerNorm+GELU,
whisper flavour).  Decoder: token embeddings + causal self-attn +
cross-attn to the encoder output.  Embeddings are tied (whisper ties the
decoder unembedding).

Serving: the encoder runs once (its output K/V for every cross-attn layer
is cached), decoder self-attn uses a standard padded KV cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.attention import attention
from repro.models.common import (
    AxisRules,
    NO_SHARD,
    maybe_scan,
    prepend_none_spec,
    shard,
    split_keys,
    stack_layers,
)
from repro.models.lm import apply_attn_block, attn_specs, init_attn
from repro.models.rope import sinusoidal_positions


def _init_enc_block(key, cfg):
    k1, k2 = split_keys(key, 2)
    return {
        "ln1": L.init_norm(cfg.d_model, cfg),
        "attn": init_attn(k1, cfg),
        "ln2": L.init_norm(cfg.d_model, cfg),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg),
    }


def _init_dec_block(key, cfg):
    k1, k2, k3 = split_keys(key, 3)
    return {
        "ln1": L.init_norm(cfg.d_model, cfg),
        "self_attn": init_attn(k1, cfg),
        "ln_x": L.init_norm(cfg.d_model, cfg),
        "cross_attn": init_attn(k2, cfg),
        "ln2": L.init_norm(cfg.d_model, cfg),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg),
    }


def init(key, cfg: ModelConfig) -> dict:
    keys = split_keys(key, cfg.encoder_layers + cfg.num_layers + 2)
    return {
        "embedding": L.init_embedding(keys[0], cfg),
        "enc_blocks": stack_layers(
            [_init_enc_block(keys[1 + i], cfg) for i in range(cfg.encoder_layers)]
        ),
        "enc_norm": L.init_norm(cfg.d_model, cfg),
        "dec_blocks": stack_layers(
            [
                _init_dec_block(keys[1 + cfg.encoder_layers + i], cfg)
                for i in range(cfg.num_layers)
            ]
        ),
        "final_norm": L.init_norm(cfg.d_model, cfg),
    }


def param_specs(cfg: ModelConfig, rules: AxisRules, tp_size: int = 1):
    enc = {
        "ln1": L.norm_specs(cfg),
        "attn": attn_specs(cfg),
        "ln2": L.norm_specs(cfg),
        "mlp": L.mlp_specs(cfg),
    }
    dec = {
        "ln1": L.norm_specs(cfg),
        "self_attn": attn_specs(cfg),
        "ln_x": L.norm_specs(cfg),
        "cross_attn": attn_specs(cfg),
        "ln2": L.norm_specs(cfg),
        "mlp": L.mlp_specs(cfg),
    }
    specs = {
        "embedding": L.embedding_specs(cfg),
        "enc_blocks": prepend_none_spec(enc),
        "enc_norm": L.norm_specs(cfg),
        "dec_blocks": prepend_none_spec(dec),
        "final_norm": L.norm_specs(cfg),
    }
    return L.resolve_specs(specs, rules)


def encode(params, frames, cfg, rules: AxisRules):
    """frames: (B, F, d) stub embeddings."""
    x = frames.astype(cfg.dtype) + sinusoidal_positions(
        frames.shape[1], cfg.d_model
    ).astype(cfg.dtype)
    x = shard(x, rules, "batch", "seq", None)
    positions = jnp.arange(frames.shape[1])

    def body(x, blk):
        h = L.apply_norm(blk["ln1"], x, cfg)
        q, k, v = (
            jnp.einsum("bsd,dhe->bshe", h, blk["attn"][w].astype(cfg.dtype))
            for w in ("wq", "wk", "wv")
        )
        o = attention(q, k, v, causal=False, chunk=cfg.attn_chunk,
                      matmul_bf16=cfg.attn_matmul_bf16)
        x = x + jnp.einsum(
            "bshe,hed->bsd", o, blk["attn"]["wo"].astype(cfg.dtype)
        )
        h2 = L.apply_norm(blk["ln2"], x, cfg)
        return x + L.apply_mlp(blk["mlp"], h2, cfg, rules), None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = maybe_scan(body, x, params["enc_blocks"], cfg.scan_layers)
    del positions
    return L.apply_norm(params["enc_norm"], x, cfg)


def _cross_attend(blk, x, enc_kv, cfg, rules):
    h = L.apply_norm(blk["ln_x"], x, cfg)
    q = jnp.einsum("bsd,dhe->bshe", h, blk["cross_attn"]["wq"].astype(cfg.dtype))
    ek, ev = enc_kv
    o = attention(q, ek, ev, causal=False, chunk=cfg.attn_chunk,
                  matmul_bf16=cfg.attn_matmul_bf16)
    return x + jnp.einsum(
        "bshe,hed->bsd", o, blk["cross_attn"]["wo"].astype(cfg.dtype)
    )


def _enc_kv(blk, enc_out, cfg):
    ek = jnp.einsum("bsd,dhe->bshe", enc_out, blk["cross_attn"]["wk"].astype(cfg.dtype))
    ev = jnp.einsum("bsd,dhe->bshe", enc_out, blk["cross_attn"]["wv"].astype(cfg.dtype))
    return ek, ev


def forward(params, batch, cfg: ModelConfig, rules: AxisRules = NO_SHARD):
    """Training: batch = {'enc_frames': (B,F,d), 'tokens': (B,S)}."""
    enc_out = encode(params, batch["enc_frames"], cfg, rules)
    x = L.embed_tokens(params["embedding"], batch["tokens"], cfg, rules)
    S = batch["tokens"].shape[1]
    pos_emb = sinusoidal_positions(S, cfg.d_model).astype(cfg.dtype)
    x = x + pos_emb
    positions = jnp.arange(S)

    def body(x, blk):
        h = L.apply_norm(blk["ln1"], x, cfg)
        a, _ = apply_attn_block(
            blk["self_attn"], h, cfg, rules, positions=positions, window=0,
            theta=cfg.rope_theta,
        )
        x = x + a
        x = _cross_attend(blk, x, _enc_kv(blk, enc_out, cfg), cfg, rules)
        h2 = L.apply_norm(blk["ln2"], x, cfg)
        return x + L.apply_mlp(blk["mlp"], h2, cfg, rules), None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = maybe_scan(body, x, params["dec_blocks"], cfg.scan_layers)
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embedding"], x, cfg, rules)
    return logits, jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    dtype = dtype or cfg.dtype
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    F = cfg.encoder_seq_len
    Lc = cfg.num_layers
    return {
        "self": (
            jnp.zeros((Lc, batch, max_len, KV, hd), dtype),
            jnp.zeros((Lc, batch, max_len, KV, hd), dtype),
        ),
        "cross": (
            jnp.zeros((Lc, batch, F, KV, hd), dtype),
            jnp.zeros((Lc, batch, F, KV, hd), dtype),
        ),
    }


def prefill(params, batch, cfg: ModelConfig, rules: AxisRules, cache: dict):
    """Encode + run the decoder prompt.  Returns (last logits, cache)."""
    enc_out = encode(params, batch["enc_frames"], cfg, rules)
    x = L.embed_tokens(params["embedding"], batch["tokens"], cfg, rules)
    S = batch["tokens"].shape[1]
    x = x + sinusoidal_positions(S, cfg.d_model).astype(cfg.dtype)
    positions = jnp.arange(S)

    def body(x, blk):
        h = L.apply_norm(blk["ln1"], x, cfg)
        a, kv = apply_attn_block(
            blk["self_attn"], h, cfg, rules, positions=positions, window=0,
            theta=cfg.rope_theta,
        )
        x = x + a
        ekv = _enc_kv(blk, enc_out, cfg)
        x = _cross_attend(blk, x, ekv, cfg, rules)
        h2 = L.apply_norm(blk["ln2"], x, cfg)
        return x + L.apply_mlp(blk["mlp"], h2, cfg, rules), (kv, ekv)

    x, (kvs, ekvs) = maybe_scan(body, x, params["dec_blocks"], cfg.scan_layers)
    ck, cv = cache["self"]
    ck = jax.lax.dynamic_update_slice(ck, kvs[0].astype(ck.dtype), (0, 0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, kvs[1].astype(cv.dtype), (0, 0, 0, 0, 0))
    cache = {"self": (ck, cv), "cross": ekvs}
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embedding"], x[:, -1:], cfg, rules)
    return logits[:, 0], cache


def decode_step(params, tokens, cfg: ModelConfig, rules: AxisRules, cache: dict, pos):
    x = L.embed_tokens(params["embedding"], tokens, cfg, rules)
    pe = sinusoidal_positions(cfg.max_seq_len, cfg.d_model).astype(cfg.dtype)
    x = x + jax.lax.dynamic_slice_in_dim(pe, pos, 1, 0)

    def body(x, xs):
        blk, (sk, sv), (ek, ev) = xs
        h = L.apply_norm(blk["ln1"], x, cfg)
        a, (nk, nv) = apply_attn_block(
            blk["self_attn"], h, cfg, rules, positions=None, window=0,
            theta=cfg.rope_theta, cache_kv=(sk, sv), pos=pos,
        )
        x = x + a
        x = _cross_attend(blk, x, (ek, ev), cfg, rules)
        h2 = L.apply_norm(blk["ln2"], x, cfg)
        return x + L.apply_mlp(blk["mlp"], h2, cfg, rules), (nk, nv)

    x, nkv = maybe_scan(
        body, x, (params["dec_blocks"], cache["self"], cache["cross"]),
        cfg.scan_layers,
    )
    cache = {"self": nkv, "cross": cache["cross"]}
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embedding"], x, cfg, rules)
    return logits[:, 0], cache
