"""Mamba2 (SSD — state-space duality) blocks: chunked scan + O(1) decode.

Implements the minimal SSD algorithm of Dao & Gu (2024): within a chunk
the recurrence is materialised as a (masked, decayed) attention-like
quadratic; across chunks only the (heads, head_dim, d_state) states flow
through an associative recurrence.  Decode is a single-step state update —
no KV cache, constant memory per sequence, which is why ``long_500k`` is
*trivial* for this family (DESIGN.md §5).

Block layout follows mamba2: in_proj → [z | xBC | dt], causal conv1d over
xBC, SSD over (x, B, C) with per-head A/D, gated RMSNorm, out_proj.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import AxisRules, dense_init, shard, split_keys


def _dims(cfg):
    s = cfg.ssm
    d_inner = cfg.d_inner
    nh = cfg.ssm_heads
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, nh, conv_dim


def init_mamba(key, cfg) -> dict:
    s = cfg.ssm
    d_inner, nh, conv_dim = _dims(cfg)
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + nh
    k1, k2, k3 = split_keys(key, 3)
    return {
        "in_proj": dense_init(k1, (cfg.d_model, d_in_proj), 0, cfg.param_dtype),
        "conv_w": dense_init(k2, (s.d_conv, conv_dim), 0, cfg.param_dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.param_dtype),
        "A_log": jnp.zeros((nh,), cfg.param_dtype),  # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), cfg.param_dtype),
        "dt_bias": jnp.zeros((nh,), cfg.param_dtype),
        "norm_scale": jnp.ones((d_inner,), cfg.param_dtype),
        "out_proj": dense_init(k3, (d_inner, cfg.d_model), 0, cfg.param_dtype),
    }


def mamba_specs(cfg) -> dict:
    return {
        "in_proj": P("fsdp", "tensor"),
        "conv_w": P(None, "tensor"),
        "conv_b": P("tensor"),
        "A_log": P(None),
        "D": P(None),
        "dt_bias": P(None),
        "norm_scale": P("tensor"),
        "out_proj": P("tensor", "fsdp"),
    }


def _split_proj(proj, cfg):
    s = cfg.ssm
    d_inner, nh, conv_dim = _dims(cfg)
    z, xBC, dt = jnp.split(proj, [d_inner, d_inner + conv_dim], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, w, b, cfg, *, state=None):
    """Depthwise causal conv1d.  xBC: (B,S,C); w: (W,C).  Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros(xBC.shape[:1] + (W - 1,) + xBC.shape[2:], xBC.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, xBC], axis=1)  # (B, S+W-1, C)
    y = sum(xp[:, i : i + xBC.shape[1]] * w[i] for i in range(W)) + b
    new_state = xp[:, -(W - 1) :] if W > 1 else pad[:, :0]
    return jax.nn.silu(y), new_state


def _segsum(x):
    """log-space segment sums: out[..., i, j] = Σ_{k=j+1..i} x[..., k] (i ≥ j)."""
    T = x.shape[-1]
    xc = jnp.cumsum(x, -1)
    diff = xc[..., :, None] - xc[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B_, C, cfg, *, init_state=None):
    """SSD over full sequences.  Shapes:
    x (B,S,nh,hd) · dt (B,S,nh) · A (nh,) · B_/C (B,S,ng,ds).
    Returns (y (B,S,nh,hd), final_state (B,nh,hd,ds))."""
    s = cfg.ssm
    Bt, S, nh, hd = x.shape
    ng, ds = B_.shape[2], B_.shape[3]
    Q = min(s.chunk_size, S)
    if S % Q:
        # zero-pad the tail: dt=0 ⇒ decay exp(0)=1 and contribution 0, so the
        # final state is exact; padded outputs are sliced off below.
        pad = Q - S % Q
        zpad = lambda a: jnp.concatenate(
            [a, jnp.zeros((Bt, pad) + a.shape[2:], a.dtype)], axis=1
        )
        x, dt, B_, C = zpad(x), zpad(dt), zpad(B_), zpad(C)
        y, final = ssd_chunked(x, dt, A, B_, C, cfg, init_state=init_state)
        return y[:, :S], final
    nc = S // Q
    rep = nh // ng

    xf = x.astype(jnp.float32)
    dA = dt * A  # (B,S,nh), negative
    # chunk views
    xc = xf.reshape(Bt, nc, Q, nh, hd)
    dtc = dt.reshape(Bt, nc, Q, nh)
    dAc = dA.reshape(Bt, nc, Q, nh).transpose(0, 3, 1, 2)  # (B,nh,nc,Q)
    Bc = B_.astype(jnp.float32).reshape(Bt, nc, Q, ng, ds)
    Cc = C.astype(jnp.float32).reshape(Bt, nc, Q, ng, ds)
    Bh = jnp.repeat(Bc, rep, axis=3)  # (B,nc,Q,nh,ds)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA_cum = jnp.cumsum(dAc, -1)  # (B,nh,nc,Q)
    # ---- intra-chunk (quadratic, attention-like)
    L = jnp.exp(_segsum(dAc))  # (B,nh,nc,Q,Q)
    xdt = xc * dtc[..., None]  # weight inputs by dt
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", Ch, Bh, L, xdt)
    # ---- chunk states
    decay_states = jnp.exp(dA_cum[..., -1:] - dA_cum)  # (B,nh,nc,Q)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", Bh, decay_states, xdt)
    # ---- inter-chunk recurrence over nc (scan)
    chunk_decay = jnp.exp(dA_cum[..., -1])  # (B,nh,nc)

    def scan_body(carry, inp):
        st, dec = inp  # st: (B,nh,hd,ds) contribution, dec: (B,nh)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit the state *entering* the chunk

    if init_state is None:
        init_state = jnp.zeros((Bt, nh, hd, ds), jnp.float32)
    final, entry_states = jax.lax.scan(
        scan_body,
        init_state,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)),
    )
    entry_states = entry_states.transpose(1, 0, 2, 3, 4)  # (B,nc,nh,hd,ds)
    # ---- contribution of entering state to each position
    state_decay = jnp.exp(dA_cum)  # (B,nh,nc,Q)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Ch, entry_states, state_decay)
    y = (y_diag + y_off).reshape(Bt, S, nh, hd)
    return y, final


def apply_mamba(p, x, cfg, rules: AxisRules, *, cache=None, pos=None):
    """Mamba2 block.  Train/prefill when cache is None; else one decode step.

    cache = {'conv': (B, W-1, conv_dim), 'ssm': (B, nh, hd, ds)}.
    Returns (y, new_cache_or_None).
    """
    s = cfg.ssm
    d_inner, nh, conv_dim = _dims(cfg)
    hd = s.head_dim
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cfg.dtype))
    z, xBC, dt_raw = _split_proj(proj, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    if cache is None or x.shape[1] > 1:
        # train (cache None) or prefill (cache present → fill it)
        conv_state = None if cache is None else cache["conv"]
        xBC, conv_tail = _causal_conv(
            xBC, p["conv_w"].astype(cfg.dtype), p["conv_b"].astype(cfg.dtype), cfg,
            state=conv_state,
        )
        xs, B_, C = jnp.split(xBC, [d_inner, d_inner + s.n_groups * s.d_state], -1)
        Bt, S = x.shape[0], x.shape[1]
        xs = xs.reshape(Bt, S, nh, hd)
        B_ = B_.reshape(Bt, S, s.n_groups, s.d_state)
        C = C.reshape(Bt, S, s.n_groups, s.d_state)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
        init_state = None if cache is None else cache["ssm"]
        y, final = ssd_chunked(xs, dt, A, B_, C, cfg, init_state=init_state)
        y = y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
        new_cache = None if cache is None else {"conv": conv_tail, "ssm": final}
    else:
        # single step: update conv state + SSM state
        w = p["conv_w"].astype(cfg.dtype)
        xp = jnp.concatenate([cache["conv"], xBC], axis=1)  # (B, W, conv)
        conv_out = jnp.einsum("bwc,wc->bc", xp, w) + p["conv_b"].astype(cfg.dtype)
        xBC_t = jax.nn.silu(conv_out)[:, None]  # (B,1,conv)
        xs, B_, C = jnp.split(xBC_t, [d_inner, d_inner + s.n_groups * s.d_state], -1)
        Bt = x.shape[0]
        xs = xs.reshape(Bt, nh, hd).astype(jnp.float32)
        B_ = B_.reshape(Bt, s.n_groups, s.d_state).astype(jnp.float32)
        C = C.reshape(Bt, s.n_groups, s.d_state).astype(jnp.float32)
        rep = nh // s.n_groups
        Bh = jnp.repeat(B_, rep, axis=1)  # (B,nh,ds)
        Chh = jnp.repeat(C, rep, axis=1)
        dt = jax.nn.softplus(
            dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
        )  # (B,nh)
        dA = jnp.exp(dt * A)  # (B,nh)
        st = cache["ssm"] * dA[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt, xs, Bh
        )
        y = jnp.einsum("bhpn,bhn->bhp", st, Chh) + xs * p["D"].astype(jnp.float32)[None, :, None]
        y = y[:, None]  # (B,1,nh,hd)
        new_cache = {"conv": xp[:, 1:], "ssm": st}
        y = y.reshape(Bt, 1, nh, hd)
    Bt, S = x.shape[0], x.shape[1]
    y = y.reshape(Bt, S, d_inner).astype(cfg.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    g = (gf * jax.lax.rsqrt(jnp.mean(jnp.square(gf), -1, keepdims=True) + 1e-6)).astype(
        cfg.dtype
    ) * p["norm_scale"].astype(cfg.dtype)
    out = jnp.einsum("bse,ed->bsd", g, p["out_proj"].astype(cfg.dtype))
    return shard(out, rules, "batch", "seq", None), new_cache


def init_mamba_cache(cfg, batch: int, dtype) -> dict:
    s = cfg.ssm
    d_inner, nh, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }
