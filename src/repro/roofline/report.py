"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table."""

from __future__ import annotations

import argparse
import json
import os


def load_cells(d: str) -> list[dict]:
    out = []
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                out.append(json.load(fh))
    return out


def fmt_s(x: float) -> str:
    return f"{x:.2e}"


def markdown_table(cells: list[dict], mesh: str = "single") -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "HBM GB/dev | fits 16G | useful FLOPs | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c["mesh"] != mesh or "roofline" not in c:
            continue
        r = c["roofline"]
        hbm = c["memory_analysis"]["total_bytes"] / 1e9
        rows.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(r['t_compute_s'])} | "
            f"{fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])} | "
            f"{r['dominant']} | {hbm:.1f} | {'yes' if hbm <= 16 else 'NO'} | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(rows)


def pick_hillclimb(cells: list[dict]) -> list[dict]:
    """worst roofline fraction / most collective-bound / most
    paper-representative (MoE with sort dispatch) among single-pod train/
    serve cells."""
    singles = [c for c in cells if c["mesh"] == "single" and "roofline" in c]
    worst = min(singles, key=lambda c: c["roofline"]["roofline_fraction"])
    coll = max(
        singles,
        key=lambda c: c["roofline"]["t_collective_s"]
        / max(c["roofline"]["bound_time_s"], 1e-12),
    )
    moes = [c for c in singles if c["arch"] in ("mixtral-8x22b", "deepseek-v2-lite-16b")
            and c["shape"] == "train_4k"]
    rep = moes[0] if moes else singles[0]
    return [worst, coll, rep]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    print(markdown_table(cells, args.mesh))
    print()
    picks = pick_hillclimb(cells)
    print("hillclimb picks:",
          [(c["arch"], c["shape"], c["roofline"]["dominant"]) for c in picks])


if __name__ == "__main__":
    main()
