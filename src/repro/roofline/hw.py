"""Target-hardware constants (TPU v5e, per assignment) + host calibration.

``V5E`` is the datasheet record the model-layer rooflines are judged
against.  ``calibrate_host()`` is its measured twin for *this* machine:
perf baselines (DESIGN.md §9) normalize wall-clock against the calibrated
peaks so a committed reference survives a hardware change — the judged
quantity is "multiples of this machine's roofline", not raw seconds.
"""

import dataclasses
import functools
import time


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_bf16_flops: float  # per chip
    hbm_bw: float  # bytes/s per chip
    ici_bw: float  # bytes/s per link (intra-pod)
    inter_pod_bw: float  # bytes/s per link (optical tier)
    hbm_bytes: float


V5E = HW(
    name="tpu-v5e",
    peak_bf16_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    inter_pod_bw=25e9,
    hbm_bytes=16e9,
)


def _copy_bandwidth(nbytes: int, repeats: int) -> float:
    """Measured memcpy bandwidth in bytes/s (read + write counted)."""
    import numpy as np

    src = np.zeros(nbytes, dtype=np.uint8)
    dst = np.empty_like(src)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        np.copyto(dst, src)
        times.append(time.perf_counter() - t0)
    return 2.0 * nbytes / float(np.median(times))


def _gemm_flops(k: int, repeats: int) -> float:
    """Measured dense f32 GEMM rate in FLOP/s (the host 'compute peak')."""
    import numpy as np

    a = np.ones((k, k), dtype=np.float32)
    b = np.ones((k, k), dtype=np.float32)
    a @ b  # BLAS thread-pool / page-fault warmup outside the timed region
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        a @ b
        times.append(time.perf_counter() - t0)
    return 2.0 * k**3 / float(np.median(times))


@functools.lru_cache(maxsize=None)
def calibrate_host(*, copy_mb: int = 64, gemm_k: int = 384, repeats: int = 5) -> HW:
    """Measure this host's effective peaks and return them as an ``HW``.

    Both probes are median-of-``repeats`` with a warmup (the measurement
    contract of ``repro.perf.measure``, inlined here so roofline stays
    importable without the perf package).  The link-tier fields reuse the
    copy bandwidth — a single host has no slower interconnect tier — and
    ``hbm_bytes`` is 0.0 (unknown/unused for normalization).  Cached: one
    calibration per process, so every case in a perfguard run is
    normalized against the same peaks.
    """
    bw = _copy_bandwidth(copy_mb << 20, repeats)
    fl = _gemm_flops(gemm_k, repeats)
    return HW(
        name="host-calibrated",
        peak_bf16_flops=fl,
        hbm_bw=bw,
        ici_bw=bw,
        inter_pod_bw=bw,
        hbm_bytes=0.0,
    )
