"""Target-hardware constants (TPU v5e, per assignment)."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_bf16_flops: float  # per chip
    hbm_bw: float  # bytes/s per chip
    ici_bw: float  # bytes/s per link (intra-pod)
    inter_pod_bw: float  # bytes/s per link (optical tier)
    hbm_bytes: float


V5E = HW(
    name="tpu-v5e",
    peak_bf16_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    inter_pod_bw=25e9,
    hbm_bytes=16e9,
)
