"""Generate the §Dry-run and §Roofline sections of EXPERIMENTS.md from the
dry-run JSON cache.  §Perf is hand-written (hypothesis→change→measure log).

    PYTHONPATH=src python -m repro.roofline.gen_experiments > experiments/tables.md
"""

from __future__ import annotations

import json
import os

from repro.roofline.report import load_cells


def one_sentence(cell) -> str:
    """What would move the dominant term down."""
    r = cell["roofline"]
    dom = r["dominant"]
    arch, shape = cell["arch"], cell["shape"]
    if dom == "compute":
        return "compute-bound: raise MXU utilisation (larger per-device batch, fuse small einsums)"
    if dom == "memory":
        if "decode" in shape or "500k" in shape:
            return "KV/state streaming bound: shrink cache dtype (bf16→int8 KV) or shard cache seq further"
        return "HBM-bound: cut f32 attention intermediates / remat traffic (fused flash kernel on TPU)"
    if r.get("coll_inter_bytes", 0) > r.get("coll_intra_bytes", 0):
        return "inter-pod bound: hierarchical (pod-aware) collectives; cross the optical tier once"
    return "ICI-bound: halve gathered bytes (bf16 params/grads), defer DP reduce out of the microbatch loop"


def dryrun_section(cells) -> str:
    out = ["## §Dry-run", ""]
    out.append(
        "Every supported (arch × shape) lowered AND compiled on both meshes "
        "(16×16 = 256-chip pod; 2×16×16 = 512 chips, 'pod' = optical tier). "
        "Sharding rules per cell: batch axes / FSDP=data / TP=model, with "
        "SP (seq→model) when head counts don't divide TP and kv_seq sharding "
        "for cache-heavy decode. Per-cell JSON in experiments/dryrun/."
    )
    out.append("")
    out.append("| arch | shape | mesh | compile s | HBM GB/dev | grad_accum | batch axes | heads | seq | kv_seq |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        ru = c["rules"]
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['compile_s']} | "
            f"{c['memory_analysis']['total_bytes']/1e9:.2f} | {c.get('grad_accum',1)} | "
            f"{ru['batch']} | {ru['heads']} | {ru['seq']} | {ru['kv_seq']} |"
        )
    return "\n".join(out)


def roofline_section(cells) -> str:
    out = ["## §Roofline", ""]
    out.append(
        "Three terms per cell (TPU v5e: 197 TF/s bf16, 819 GB/s HBM, "
        "50 GB/s ICI, 25 GB/s inter-pod), from CALIBRATED per-device "
        "costs (small unrolled lowers reconstruct true per-step FLOPs/bytes/"
        "collective traffic — XLA cost_analysis counts while-bodies once; "
        "see launch/dryrun.py).  MODEL_FLOPS = 6·N·D (train) or 2·N·D "
        "(serve), N = active params for MoE.  useful = MODEL_FLOPS / "
        "(HLO FLOPs × devices).  roofline frac = ideal-compute time / "
        "bound-term time."
    )
    out.append("")
    for mesh in ("single", "multi"):
        out.append(f"### {mesh}-pod mesh")
        out.append("")
        out.append("| arch | shape | compute s | memory s | collective s (intra/inter GB) | dominant | useful | roofline frac | next lever |")
        out.append("|---|---|---|---|---|---|---|---|---|")
        for c in cells:
            if c["mesh"] != mesh or "roofline" not in c:
                continue
            r = c["roofline"]
            out.append(
                f"| {c['arch']} | {c['shape']} | {r['t_compute_s']:.2e} | "
                f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} "
                f"({r['coll_intra_bytes']/1e9:.1f}/{r['coll_inter_bytes']/1e9:.1f}) | "
                f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
                f"{r['roofline_fraction']:.3f} | {one_sentence(c)} |"
            )
        out.append("")
    return "\n".join(out)


def variants_section(cells) -> str:
    out = ["### §Perf lever variants (baseline rows above; deltas in EXPERIMENTS.md §Perf)", ""]
    out.append("| arch | shape | mesh | levers | compute s | memory s | collective s | HBM GB/dev | roofline frac |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        if "roofline" not in c:
            continue
        r = c["roofline"]
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {'+'.join(c['levers'])} | "
            f"{r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} | "
            f"{r['t_collective_s']:.2e} | "
            f"{c['memory_analysis']['total_bytes']/1e9:.2f} | "
            f"{r['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


def main():
    cells = load_cells("experiments/dryrun")
    base = [c for c in cells if not c.get("levers")]
    tagged = [c for c in cells if c.get("levers")]
    print(dryrun_section(base))
    print()
    print(roofline_section(base))
    print()
    print(variants_section(tagged))


if __name__ == "__main__":
    main()
