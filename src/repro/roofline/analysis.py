"""Three-term roofline from a compiled dry-run artifact.

    compute    = HLO_FLOPs(per device)        / peak_FLOP/s
    memory     = HLO_bytes(per device)        / HBM_bw
    collective = collective_bytes(per device) / link_bw   (per link class)

``cost_analysis()`` on a partitioned computation reports **per-device**
flops/bytes (verified against a hand-checked einsum).  Collective traffic
is not in cost_analysis — we parse the post-SPMD optimized HLO
(``compiled.as_text()``): every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute op's *output shape* bytes are accumulated,
split by whether its replica group spans the pod axis (inter-pod = slow
"optical" tier) or stays inside a pod (ICI).

Inter-pod detection: with mesh (pod=2, data=16, model=16) laid out
major-to-minor, two device ids in the same group that differ by ≥ 256 can
only be in different pods.
"""

from __future__ import annotations

import re

from repro.roofline.hw import HW, V5E

COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"(?:\(([^)]*)\)|([a-z0-9\[\],{}<>= ]+?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u32|s16|u16|s8|u8|pred)\[([\d,]*)\]")
GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]")
GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")
PAIRS_RE = re.compile(r"source_target_pairs=\{([^}]*)\}")

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}


def _line_bytes(line: str) -> int:
    """Bytes of every tensor in the op's output shape(s)."""
    # only look at the segment before the operand list's '(' to avoid
    # counting operand shapes; the '=' left side has the output shape(s).
    head = line.split("(", 1)[0]
    total = 0
    for dt, dims in SHAPE_RE.findall(head):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _crosses_pod(line: str, pod_block: int) -> bool:
    """Does this collective's group span device-id blocks of `pod_block`?"""
    m = GROUPS_RE.search(line)
    if m:
        n_groups, g_size = int(m.group(1)), int(m.group(2))
        # iota groups: consecutive-ids <=[perm] — group spans pods iff its
        # id-range covers more than one pod block under the transpose.  A
        # conservative exact check: reconstruct the first group.
        dims = [int(x) for x in m.group(3).split(",")]
        total = 1
        for d in dims:
            total *= d
        if g_size > 1:
            # devices in one group under iota layout differ by strides of
            # the minor axes; group crosses pods iff g_size * stride
            # reaches beyond a pod block.  Parse transpose if present.
            tmatch = re.search(r"T\(([\d,]+)\)", line)
            import numpy as np

            ids = np.arange(total)
            if tmatch:
                perm = [int(x) for x in tmatch.group(1).split(",")]
                ids = ids.reshape(dims).transpose(perm).reshape(-1)
            groups = ids.reshape(n_groups, g_size)
            return bool((groups // pod_block != groups[:, :1] // pod_block).any())
        return False
    m = GROUPS_LIST_RE.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in re.findall(r"\d+", grp)]
            if ids and (max(ids) // pod_block) != (min(ids) // pod_block):
                return True
        return False
    m = PAIRS_RE.search(line)
    if m:
        for pair in re.findall(r"\{(\d+),(\d+)\}", "{" + m.group(1) + "}"):
            a, b = int(pair[0]), int(pair[1])
            if a // pod_block != b // pod_block:
                return True
    return False


def _head_shapes(line: str):
    head = line.split("(", 1)[0]
    return [(dt, tuple(int(d) for d in dims.split(",") if d))
            for dt, dims in SHAPE_RE.findall(head)]


def collective_bytes(
    hlo_text: str,
    *,
    num_devices: int,
    pod_block: int | None = None,
    halve_param_shapes: "set[tuple[int, ...]] | None" = None,
):
    """Sum collective op bytes from post-SPMD HLO.

    Returns dict with total/intra/inter bytes (PER DEVICE — HLO shapes in
    SPMD are already the per-device shard shapes) and per-op-kind totals.

    ``halve_param_shapes``: CPU-backend correction.  The CPU XLA backend
    upcasts bf16 dots to f32 and hoists the convert BEFORE weight
    all-gathers / gradient all-reduces, so with bf16 params the HLO still
    shows f32 weight collectives (2× the TPU bytes).  When the caller
    intends bf16 params, pass the set of (full and transposed) parameter
    shapes; f32 collectives whose tensor shape matches are counted at
    half width.  Applied mechanically and identically across baseline and
    optimized variants — deltas remain meaningful.
    """
    out = {"total": 0, "intra_pod": 0, "inter_pod": 0, "by_kind": {}, "count": 0,
           "halved": 0}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.match(line)
        if not m:
            continue
        kind = m.group(3)
        b = _line_bytes(line)
        if halve_param_shapes:
            for dt, shp in _head_shapes(line):
                if dt == "f32" and shp in halve_param_shapes:
                    cut = (np_prod(shp) * 4) // 2
                    b -= cut
                    out["halved"] += cut
        out["total"] += b
        out["count"] += 1
        out["by_kind"][kind] = out["by_kind"].get(kind, 0) + b
        if pod_block and _crosses_pod(line, pod_block):
            out["inter_pod"] += b
        else:
            out["intra_pod"] += b
    return out


def np_prod(shp):
    n = 1
    for d in shp:
        n *= d
    return n


def param_shape_set(params_shape_tree) -> set:
    """Full + transposed 2-D(+) parameter shapes for the CPU-upcast fix."""
    import jax

    out = set()
    for leaf in jax.tree.leaves(params_shape_tree):
        shp = tuple(int(x) for x in leaf.shape)
        if len(shp) >= 2:
            out.add(shp)
            out.add(tuple(reversed(shp)))
            # layer-stacked variants appear unstacked in unrolled HLO
            if len(shp) >= 3:
                out.add(shp[1:])
                out.add(tuple(reversed(shp[1:])))
    return out


def bound_time_s(
    *,
    flops: float = 0.0,
    bytes_moved: float = 0.0,
    intra_pod_bytes: float = 0.0,
    inter_pod_bytes: float = 0.0,
    hw: HW = V5E,
) -> float:
    """Roofline lower bound on wall time for an abstract workload.

    The same three-term max as :func:`roofline_from_compiled`, but over
    caller-supplied workload numbers instead of a compiled artifact — the
    shared arithmetic behind the perf subsystem's machine normalization
    (``repro.perf.normalize``, DESIGN.md §9).
    """
    t_compute = flops / hw.peak_bf16_flops if flops else 0.0
    t_memory = bytes_moved / hw.hbm_bw if bytes_moved else 0.0
    t_coll = 0.0
    if intra_pod_bytes:
        t_coll += intra_pod_bytes / hw.ici_bw
    if inter_pod_bytes:
        t_coll += inter_pod_bytes / hw.inter_pod_bw
    return max(t_compute, t_memory, t_coll)


def roofline_from_compiled(
    compiled,
    *,
    num_devices: int,
    pod_block: int | None = None,
    hw: HW = V5E,
    model_flops: float | None = None,
) -> dict:
    """The §Roofline record for one (arch × shape × mesh) cell."""
    from repro import compat

    ca = compat.cost_analysis(compiled)
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo, num_devices=num_devices, pod_block=pod_block)
    t_compute = flops_dev / hw.peak_bf16_flops
    t_memory = bytes_dev / hw.hbm_bw
    t_coll = coll["intra_pod"] / hw.ici_bw + coll["inter_pod"] / hw.inter_pod_bw
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mem = compiled.memory_analysis()
    rec = {
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes": coll,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_time_s": max(terms.values()),
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "total_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
    }
    if model_flops is not None:
        total_hlo_flops = flops_dev * num_devices
        rec["model_flops"] = model_flops
        rec["useful_flops_ratio"] = model_flops / total_hlo_flops if total_hlo_flops else 0.0
        rec["mfu_bound"] = (
            (model_flops / num_devices / hw.peak_bf16_flops) / rec["bound_time_s"]
            if rec["bound_time_s"] > 0
            else 0.0
        )
    return rec


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D train; 2·N·D_active per generated token batch for
    decode; 2·N·D for prefill.  MoE uses active params."""
    n = cfg.param_count()
    if cfg.is_moe:
        m = cfg.moe
        total_e = 3 * cfg.d_model * m.expert_d_ff * m.num_experts
        active_e = 3 * cfg.d_model * m.expert_d_ff * m.num_experts_per_tok
        n = n - cfg.num_layers * (total_e - active_e)
    d_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * d_tokens
