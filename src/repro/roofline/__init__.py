from repro.roofline.hw import V5E
from repro.roofline.analysis import roofline_from_compiled, collective_bytes

__all__ = ["V5E", "roofline_from_compiled", "collective_bytes"]
