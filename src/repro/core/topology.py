"""OTIS Hyper Hexa-Cell (OHHC) interconnection topology.

Faithful construction of the interconnect from the paper (§1.4–1.5):

* A **1-dimensional HHC** is 6 processors arranged as two fully-connected
  triangles, plus one cross edge per node pairing the triangles
  (Fig 1.1).  The algorithm in §3.2(a) uses the pairing
  ``5↔0, 3↔1, 4↔2`` (node 5 sends *directly* to node 0; 3→1, 4→2), so we
  adopt exactly that pairing for the cross edges.

* A **d_h-dimensional HHC** replaces every vertex of a (d_h−1)-dimensional
  hypercube with a 1-D HHC (Fig 1.2).  It therefore contains
  ``2**(d_h−1)`` HHC cells ("HHC groups") of 6 nodes each, i.e.
  ``P(d_h) = 6·2**(d_h−1)`` processors.  Hypercube edges connect *every*
  node of a cell to the same-position node of the cell whose index differs
  in one bit (the standard HHC construction: uniform degree
  ``3 + (d_h−1)``, HHC diameter ``d_h + 1``, and hence OHHC diameter
  ``2·d_h + 3 = 2·(d_h+1) + 1`` — the OTIS rule ``2·d(factor) + 1``).
  The accumulation algorithm in Fig 3.2 only ever *uses* the head-to-head
  links (node 0 of each cell), which are a subset of this wiring.

* An **OHHC** is ``G`` HHC groups joined by optical OTIS links:
  node ``x`` of group ``y`` ↔ node ``y`` of group ``x`` (§3.2(c)).
  Two variants (Table 1.1):  ``G = P`` ("full") and ``G = P/2`` ("half").

Table 1.1 reproduction::

    d_h   G=P  (groups, procs)   G=P/2 (groups, procs)
    1     (6,   36)              (3,   18)
    2     (12,  144)             (6,   72)
    3     (24,  576)             (12,  288)
    4     (48,  2304)            (24,  1152)

Addressing: a processor is ``(group, local)`` with
``local = 6*hhc_group + hhc_node``; its *global id* is
``group * P + local``.  Chunk/bucket ``k`` of the value-range partition is
owned by global id ``k`` so that gathering in global-id order yields the
sorted array (§3.1).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Iterator

HHC_SIZE = 6

# Cross edges pairing the two triangles, exactly as used by the
# accumulation rules of Fig 3.1 (5→0, 3→1, 4→2).
_CROSS_PAIRS = ((0, 5), (1, 3), (2, 4))
# Each triangle is fully connected.
_TRIANGLES = ((0, 1, 2), (3, 4, 5))


def hhc_cell_edges() -> list[tuple[int, int]]:
    """Undirected edges of a single 1-D HHC cell (Fig 1.1): 6 triangle + 3 cross."""
    edges = []
    for tri in _TRIANGLES:
        a, b, c = tri
        edges += [(a, b), (a, c), (b, c)]
    edges += list(_CROSS_PAIRS)
    return edges


@dataclasses.dataclass(frozen=True)
class OHHCTopology:
    """An OHHC instance: ``d_h`` ∈ {1,2,3,4,...}, ``variant`` ∈ {'full','half'}.

    ``variant='full'``  → G = P   (paper's "full group" OHHC)
    ``variant='half'``  → G = P/2 (paper's "half group" OHHC)
    """

    d_h: int
    variant: str = "full"

    def __post_init__(self):
        if self.d_h < 1:
            raise ValueError(f"d_h must be >= 1, got {self.d_h}")
        if self.variant not in ("full", "half"):
            raise ValueError(f"variant must be 'full' or 'half', got {self.variant!r}")

    # ---- sizes (Table 1.1) -------------------------------------------------
    @property
    def num_hhc_cells(self) -> int:
        """HHC cells per group = hypercube vertices = 2**(d_h-1)."""
        return 1 << (self.d_h - 1)

    @property
    def procs_per_group(self) -> int:
        """P = 6 · 2**(d_h−1)."""
        return HHC_SIZE * self.num_hhc_cells

    @property
    def num_groups(self) -> int:
        """G = P (full) or P/2 (half)."""
        p = self.procs_per_group
        return p if self.variant == "full" else p // 2

    @property
    def total_procs(self) -> int:
        return self.num_groups * self.procs_per_group

    # ---- addressing ---------------------------------------------------------
    def global_id(self, group: int, local: int) -> int:
        return group * self.procs_per_group + local

    def addr(self, gid: int) -> tuple[int, int]:
        """global id → (group, local)."""
        return divmod(gid, self.procs_per_group)

    @staticmethod
    def split_local(local: int) -> tuple[int, int]:
        """local → (hhc_cell, hhc_node)."""
        return divmod(local, HHC_SIZE)

    # ---- links --------------------------------------------------------------
    def electrical_neighbors(self, local: int) -> list[int]:
        """Intra-group neighbours of a local index (triangles + cross + hypercube)."""
        cell, node = self.split_local(local)
        out = []
        # triangle edges
        for tri in _TRIANGLES:
            if node in tri:
                out += [cell * HHC_SIZE + m for m in tri if m != node]
        # cross edge
        for a, b in _CROSS_PAIRS:
            if node == a:
                out.append(cell * HHC_SIZE + b)
            elif node == b:
                out.append(cell * HHC_SIZE + a)
        # hypercube edges: every node links to its same-position counterpart
        # in each bit-adjacent cell (uniform degree 3 + d_h − 1)
        for bit in range(self.d_h - 1):
            out.append((cell ^ (1 << bit)) * HHC_SIZE + node)
        return sorted(out)

    def optical_partner(self, group: int, local: int) -> tuple[int, int] | None:
        """OTIS rule: node x of group y ↔ node y of group x.

        No link when ``local ≥ G`` (the half variant's upper nodes have no
        transpose image) or at the self-transpose hole ``local == group``,
        where the rule maps (g, g) to itself.
        """
        if local >= self.num_groups or local == group:
            return None
        return (local, group)

    def electrical_edges(self) -> Iterator[tuple[int, int]]:
        """All undirected electrical edges as (gid_a, gid_b), a < b."""
        p = self.procs_per_group
        for g in range(self.num_groups):
            for local in range(p):
                for nb in self.electrical_neighbors(local):
                    a, b = self.global_id(g, local), self.global_id(g, nb)
                    if a < b:
                        yield (a, b)

    def optical_edges(self) -> Iterator[tuple[int, int]]:
        """All undirected optical edges as (gid_a, gid_b), a < b."""
        for g in range(self.num_groups):
            for local in range(self.procs_per_group):
                partner = self.optical_partner(g, local)
                if partner is not None:
                    a = self.global_id(g, local)
                    b = self.global_id(*partner)
                    if a < b:
                        yield (a, b)

    # ---- diagnostics ---------------------------------------------------------
    def electrical_edge_count_closed_form(self) -> int:
        """Per group: 9 intra-cell edges per cell + 6·(d_h−1)/2 hypercube
        edges per cell = 3·cells·(d_h+2); times G groups."""
        return self.num_groups * 3 * self.num_hhc_cells * (self.d_h + 2)

    def optical_edge_count_closed_form(self) -> int:
        """One transpose link per unordered group pair: G·(G−1)/2 (the
        diagonal (g,g) and, for the half variant, locals ≥ G have none)."""
        g = self.num_groups
        return g * (g - 1) // 2

    @functools.cached_property
    def summary(self) -> dict:
        return {
            "d_h": self.d_h,
            "variant": self.variant,
            "groups": self.num_groups,
            "procs_per_group": self.procs_per_group,
            "total_procs": self.total_procs,
            "hhc_cells_per_group": self.num_hhc_cells,
            "electrical_edges": sum(1 for _ in self.electrical_edges()),
            "optical_edges": sum(1 for _ in self.optical_edges()),
        }


def table_1_1() -> dict[tuple[int, str], tuple[int, int]]:
    """Reproduce Table 1.1: (d_h, variant) → (#groups, #processors)."""
    out = {}
    for d_h in (1, 2, 3, 4):
        for variant in ("full", "half"):
            t = OHHCTopology(d_h, variant)
            out[(d_h, variant)] = (t.num_groups, t.total_procs)
    return out
