"""Beyond-paper distributed sample sort: cost model + simulated path.

Differences vs the paper's algorithm (see DESIGN.md §2):

1. **Balanced splitters** (sampled quantiles) instead of equal-width value
   ranges → bucket sizes balanced under any input distribution (the
   paper's 'local distribution' collapse disappears).
2. **One fused exchange** (all-to-all) instead of the store-and-forward
   spanning tree → communication is a single collective the compiler can
   schedule/overlap, and the result stays *sharded* (shard i ≤ shard i+1)
   rather than funnelled to one node.
3. **Hierarchy-aware two-level exchange** on a multi-pod mesh: intra-pod
   all-to-all first, then exactly one inter-pod exchange — preserving the
   paper's "cross the optical tier once" principle.

The real-mesh implementation lives in ``repro.core.dist_sort``; here we
keep the analytic cost model (used by benchmarks to compare against the
paper-schedule model) and a host-side reference.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.ohhc_sort import LinkModel
from repro.core.topology import OHHCTopology


@dataclasses.dataclass(frozen=True)
class ExchangeModel:
    """All-to-all cost on a two-tier network.

    Per device: sends (P−1)/P of its n/P elements.  Intra-pod traffic rides
    electrical links; the inter-pod fraction crosses the optical tier once.
    """

    link: LinkModel = LinkModel()

    def all_to_all_time_s(
        self,
        n_total: int,
        itemsize: int,
        devices: int,
        pods: int = 1,
    ) -> float:
        per_dev = n_total / devices
        send_bytes = per_dev * (devices - 1) / devices * itemsize
        if pods <= 1:
            return self.link.alpha_us * 1e-6 + send_bytes / (
                self.link.electrical_gbps * 1e9
            )
        # two-level: intra-pod portion + one inter-pod crossing
        inter_frac = (pods - 1) / pods
        intra = send_bytes * (1 - inter_frac) / (self.link.electrical_gbps * 1e9)
        inter = send_bytes * inter_frac / (self.link.optical_gbps * 1e9)
        return 2 * self.link.alpha_us * 1e-6 + intra + inter


def sample_sort_host(x: np.ndarray, num_shards: int, *, oversample: int = 32):
    """Host reference: returns (shards list, splitters).  Each shard sorted,
    shard i's max ≤ shard i+1's min; concatenation is the sorted array."""
    x = np.asarray(x).ravel()
    s = min(x.size, oversample * num_shards)
    sample = np.sort(x[:: -(-x.size // s)])
    splitters = sample[(np.arange(1, num_shards) * sample.size) // num_shards]
    ids = np.searchsorted(splitters, x, side="right")
    shards = [np.sort(x[ids == i], kind="quicksort") for i in range(num_shards)]
    return shards, splitters


def imbalance(bucket_sizes: np.ndarray) -> float:
    """max/mean bucket population — 1.0 is perfectly balanced."""
    m = float(np.mean(bucket_sizes))
    return float(np.max(bucket_sizes)) / m if m > 0 else float("inf")


def compare_schedules(
    topo: OHHCTopology,
    n_total: int,
    itemsize: int = 4,
    link: LinkModel = LinkModel(),
) -> dict:
    """Analytic comm-time comparison: paper spanning-tree vs fused exchange."""
    from repro.core.ohhc_sort import model_comm_time_s
    from repro.core.schedule import AccumulationSchedule

    sched = AccumulationSchedule.build(topo)
    even = [n_total // topo.total_procs] * topo.total_procs
    paper_t = model_comm_time_s(sched, even, link, itemsize)
    fused_t = ExchangeModel(link).all_to_all_time_s(
        n_total, itemsize, topo.total_procs, pods=topo.num_groups
    )
    return {
        "paper_schedule_s": paper_t,
        "fused_exchange_s": fused_t,
        "speedup": paper_t / fused_t if fused_t > 0 else float("inf"),
    }
