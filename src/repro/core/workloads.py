"""Workload layer: host-side helpers behind the engine's new operations
(DESIGN.md §12).

The paper evaluates one operation — full quicksort — across dimensions,
array types, and sizes.  This module holds the exact host-side arithmetic
that lets the engine vary the *operation* instead, while staying on the
paper's value-range partitioning:

* ``host_bucket_ids`` — the Array Division Procedure's equal-width bucket
  rule (§3.1) evaluated exactly in numpy unsigned arithmetic, bit-for-bit
  identical to the traced rule inside the simulated sort.  Because the
  plan-time histogram and the kernel agree exactly, top-k cut decisions
  and capacities are never sampled guesses.
* ``topk_cut`` — the top-k skip rule: the smallest prefix of buckets whose
  cumulative count covers ``k``; every bucket past the cut is wholly past
  rank ``k`` and is never sorted.
* ``host_top_k`` — the host executor: bucket, cut, sort only the kept
  prefix, slice the head.
* ``merge_sorted_arrays`` — the O(n+m) streaming-merge gather
  (``searchsorted`` positions + boolean-mask scatter), the merge-free
  gather idea applied across *time* instead of across processors.

Everything here is plain numpy — no jax import — so the engine can call
it during planning without touching the accelerator.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "WORKLOAD_OPS",
    "TopKTooLarge",
    "host_bucket_ids",
    "topk_cut",
    "host_top_k",
    "check_sorted",
    "merge_sorted_arrays",
]

# The engine's operation axis (mirrored by the verify grid's op cells).
WORKLOAD_OPS = ("sort", "top_k", "pairs_pytree", "merge")


class TopKTooLarge(ValueError):
    """``top_k(keys, k)`` was asked for more elements than exist."""


def host_bucket_ids(x: np.ndarray, num_buckets: int) -> np.ndarray:
    """Exact equal-width bucket ids, matching the simulated kernel's rule.

    Integer dtypes use the same unsigned-wraparound arithmetic as the
    traced path (`width = (hi - lo) // P + 1` in uint32/uint64), so the
    histogram computed here is exactly the histogram the kernel will
    scatter — the contract the top-k planner relies on.  Floats use the
    same float32/float64 safe-width rule.
    """
    x = np.asarray(x).ravel()
    if x.size == 0:
        return np.zeros(0, dtype=np.int64)
    lo, hi = x.min(), x.max()
    if np.issubdtype(x.dtype, np.integer):
        u = np.uint64 if x.dtype.itemsize == 8 else np.uint32
        # two's-complement wraparound is the exactness mechanism here
        # (signed span via unsigned subtraction), not an error
        with np.errstate(over="ignore"):
            lo_u = lo.astype(u)
            width = (hi.astype(u) - lo_u) // u(num_buckets) + u(1)
            ids = ((x.astype(u) - lo_u) // width).astype(np.int64)
    else:
        f = np.float64 if x.dtype == np.float64 else np.float32
        lo_f = lo.astype(f)
        width = (hi.astype(f) - lo_f) / f(num_buckets)
        if not width > 0:
            width = f(1.0)
        ids = np.floor((x.astype(f) - lo_f) / width).astype(np.int64)
    return np.clip(ids, 0, num_buckets - 1)


def topk_cut(counts: np.ndarray, k: int) -> tuple[int, int]:
    """Top-k skip rule: ``(keep, skipped)`` bucket counts for rank ``k``.

    ``keep`` is the smallest prefix length with ``sum(counts[:keep]) >= k``;
    the remaining ``skipped`` buckets hold only values past rank ``k`` (the
    equal-width rule orders buckets by value range) and need never be
    sorted.
    """
    counts = np.asarray(counts)
    c = np.cumsum(counts)
    keep = int(np.searchsorted(c, max(int(k), 1), side="left")) + 1
    keep = min(keep, counts.size)
    return keep, counts.size - keep


def host_top_k(
    x: np.ndarray, k: int, num_buckets: int
) -> tuple[np.ndarray, dict]:
    """Host top-k executor: bucket, cut, sort only the kept prefix.

    Returns ``(head, info)`` where ``head == np.sort(x)[:k]`` exactly and
    ``info`` reports the skip accounting (kept/skipped buckets, kept
    element count).
    """
    x = np.asarray(x).ravel()
    k = int(k)
    if k <= 0:
        return x[:0].copy(), {
            "keep_buckets": 0,
            "skipped_buckets": num_buckets,
            "kept_count": 0,
        }
    ids = host_bucket_ids(x, num_buckets)
    counts = np.bincount(ids, minlength=num_buckets)
    keep, skipped = topk_cut(counts, k)
    kept = x[ids < keep]
    head = np.sort(kept)[:k]
    return head, {
        "keep_buckets": keep,
        "skipped_buckets": skipped,
        "kept_count": int(kept.size),
    }


def check_sorted(buf: np.ndarray) -> bool:
    """True when ``buf`` is ascending (ties allowed)."""
    buf = np.asarray(buf).ravel()
    if buf.size <= 1:
        return True
    return bool(np.all(buf[:-1] <= buf[1:]))


def merge_sorted_arrays(
    sorted_buf: np.ndarray, new_sorted: np.ndarray, *, check: bool = False
) -> np.ndarray:
    """Merge two ascending arrays in O(n + m) — no re-sort.

    The gather twin of the paper's merge-free accumulation: every element
    of ``new_sorted`` lands at ``searchsorted(buf, v, 'right') + rank``
    (ties insert after existing equals, keeping the merge stable in the
    buffer-first sense), and the buffer elements fill the remaining slots
    in order.  With ``check=True`` both inputs are validated ascending
    (O(n + m)), the service-boundary contract for ``Sortd`` merge batches.
    """
    a = np.asarray(sorted_buf).ravel()
    b = np.asarray(new_sorted).ravel()
    if a.dtype != b.dtype:
        raise ValueError(
            f"merge_sorted: dtype mismatch — buffer {a.dtype} vs new {b.dtype}"
        )
    if check:
        if not check_sorted(a):
            raise ValueError("merge_sorted: sorted_buf is not ascending")
        if not check_sorted(b):
            raise ValueError("merge_sorted: new keys are not ascending")
    if b.size == 0:
        return a.copy()
    if a.size == 0:
        return b.copy()
    out = np.empty(a.size + b.size, dtype=a.dtype)
    pos_b = np.searchsorted(a, b, side="right") + np.arange(b.size)
    mask = np.zeros(out.size, dtype=bool)
    mask[pos_b] = True
    out[mask] = b
    out[~mask] = a
    return out
