"""Array Division Procedure (§3.1) + beyond-paper balanced splitters.

The paper routes element ``v`` to bucket ``⌊(v − min) / SubDivider⌋`` with
``SubDivider = (max − min) / P``.  (The paper's formula omits the ``− min``
shift; without it, any array whose minimum is far from 0 lands every
element in a handful of buckets, so we include the shift — the obvious
intended semantics.)  This is *range partitioning*: bucket i's values are
all ≤ bucket i+1's, hence concatenation after per-bucket sorting is sorted
with **no merge step** — the paper's central trick.

Weakness the paper itself measures (its "local distribution" runs reach
only ~10% speedup): equal-width value ranges collapse under skew.  The
beyond-paper fix is classic sample sort: take an oversampled random/strided
sample, sort it, use its quantiles as splitters.  Bucket population is then
balanced to within a provable factor regardless of the value distribution.

Everything here is pure ``jnp`` and jit-safe; the Pallas kernel twins live
in ``repro.kernels`` (bucket histogram/rank via one-hot MXU matmul).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def max_sentinel(dtype):
    """Typed dtype-max scalar (pad fill that sorts to the end).

    Must carry ``dtype`` explicitly: a bare Python int (uint32's
    4294967295) is weak-typed int32 by jax and overflows at trace time
    wherever it reaches ``jnp.where``/arguments directly.
    """
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray(jnp.iinfo(dtype).max, dtype)
    return jnp.asarray(jnp.inf, dtype)


def min_sentinel(dtype):
    """Typed dtype-min scalar (masked out of max computations)."""
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray(jnp.iinfo(dtype).min, dtype)
    return jnp.asarray(-jnp.inf, dtype)


def default_capacity(n: int, num_buckets: int) -> int:
    """The legacy fixed bucket capacity: ``2·ceil(n/P)`` rounded up to 8.

    Safe for near-uniform inputs only; ``repro.core.engine`` replaces it
    with a measured estimate (DESIGN.md §4) and keeps this as the floor.
    """
    cap = int(-(-2 * n // num_buckets))
    cap += (-cap) % 8
    return cap


def pack_segments(
    keys,
    seg_lens,
    row_len: int,
    *,
    fill_value=None,
    align: str = "left",
) -> np.ndarray:
    """Pack ``B`` concatenated variable-length segments into a ``(B, row_len)``
    dense matrix — the host half of the segmented batch path (DESIGN.md §8).

    ``keys`` is the flat concatenation of the segments, ``seg_lens`` their
    lengths in order.  This is a *host* (numpy) utility on purpose: requests
    arrive as host arrays, and one vectorized boolean-mask scatter packs the
    whole batch in a single pass — the device then sees exactly one
    ``(B, row_len)`` transfer instead of ``B`` small ones.

    ``align='left'`` places each segment at the row start (the sort layout:
    the valid prefix is ``row[:len]``); ``align='right'`` right-aligns the
    content (the serving left-pad layout — token ends line up so decode
    positions agree across the batch).  ``fill_value`` defaults to the dtype
    max so left-aligned pad tails sort to the end.
    """
    keys = np.asarray(keys).ravel()
    lens = np.asarray(seg_lens, dtype=np.int64).ravel()
    if (lens < 0).any():
        raise ValueError("pack_segments: negative segment length")
    if int(lens.sum()) != keys.size:
        raise ValueError(
            f"pack_segments: seg_lens sum to {int(lens.sum())} "
            f"but keys has {keys.size} elements"
        )
    if lens.size and int(lens.max()) > row_len:
        raise ValueError(
            f"pack_segments: longest segment ({int(lens.max())}) "
            f"exceeds row_len ({row_len})"
        )
    if fill_value is None:
        fill_value = (
            np.iinfo(keys.dtype).max
            if np.issubdtype(keys.dtype, np.integer)
            else np.inf
        )
    out = np.full((lens.size, row_len), fill_value, keys.dtype)
    pos = np.arange(row_len)[None, :]
    if align == "left":
        mask = pos < lens[:, None]
    elif align == "right":
        mask = pos >= row_len - lens[:, None]
    else:
        raise ValueError(f"pack_segments: unknown align {align!r}")
    # Row-major mask assignment consumes ``keys`` in concatenation order.
    out[mask] = keys
    return out


def unpack_segments(padded, seg_lens) -> list[np.ndarray]:
    """Inverse of :func:`pack_segments` (left-aligned): row prefixes as copies."""
    padded = np.asarray(padded)
    lens = np.asarray(seg_lens, dtype=np.int64).ravel()
    if padded.shape[0] != lens.size:
        raise ValueError(
            f"unpack_segments: {padded.shape[0]} rows vs {lens.size} lengths"
        )
    return [padded[i, : int(n)].copy() for i, n in enumerate(lens)]


def paper_bucket_ids(x: jax.Array, num_buckets: int) -> jax.Array:
    """§3.1: equal-width value-range bucket ids in ``[0, num_buckets)``.

    Float-based and therefore NOT exact for integer keys above 2^24 — the
    engine's sim path uses the exact unsigned-integer rule instead
    (``engine._paper_ids``), whose bit-identical host twin is
    ``repro.core.workloads.host_bucket_ids`` (re-exported below).  Use
    that pair whenever a host-side histogram must predict the kernel's
    scatter exactly (the top-k planner's contract, DESIGN.md §12).
    """
    x = jnp.asarray(x)
    lo = jnp.min(x).astype(jnp.float64 if x.dtype == jnp.int64 else jnp.float32)
    hi = jnp.max(x).astype(lo.dtype)
    width = (hi - lo) / num_buckets
    # Degenerate constant array → everything in bucket 0 (paper leaves this
    # implicit; division by zero would occur otherwise).
    safe_width = jnp.where(width > 0, width, 1.0)
    ids = jnp.floor((x.astype(lo.dtype) - lo) / safe_width).astype(jnp.int32)
    return jnp.clip(ids, 0, num_buckets - 1)


def sampled_splitters(
    x: jax.Array, num_buckets: int, *, oversample: int = 32, key: jax.Array | None = None
) -> jax.Array:
    """Beyond-paper: ``num_buckets − 1`` splitters from an oversampled sample.

    Deterministic strided sampling by default (reproducible, collective-free
    when used per-shard); pass ``key`` for random sampling.
    """
    x = jnp.asarray(x).ravel()
    n = x.shape[0]
    s = min(n, max(num_buckets * oversample, num_buckets))
    if key is not None:
        idx = jax.random.randint(key, (s,), 0, n)
        sample = x[idx]
    else:
        # ceil-stride so the strided sample spans the WHOLE array (a floor
        # stride + truncation would sample only the head — catastrophic for
        # sorted inputs).
        stride = -(-n // s)
        sample = x[::stride]
    sample = jnp.sort(sample)
    # splitter i = quantile (i+1)/num_buckets of the sample
    pos = (jnp.arange(1, num_buckets) * sample.shape[0]) // num_buckets
    return sample[pos]


def splitter_bucket_ids(x: jax.Array, splitters: jax.Array) -> jax.Array:
    """Bucket ids via searchsorted on sorted splitters (len = buckets − 1)."""
    return jnp.searchsorted(splitters, jnp.asarray(x), side="right").astype(jnp.int32)


def bucket_counts(bucket_ids: jax.Array, num_buckets: int) -> jax.Array:
    """Histogram of bucket ids, shape (num_buckets,) int32."""
    return jnp.zeros(num_buckets, jnp.int32).at[bucket_ids].add(1)


def bucket_ranks(bucket_ids: jax.Array, num_buckets: int) -> jax.Array:
    """Rank of each element within its bucket (stable, order-of-appearance).

    rank[i] = #{j < i : bucket_ids[j] == bucket_ids[i]}.  Implemented as a
    cumulative sum over the one-hot bucket matrix — the same formulation the
    Pallas ``partition_kernel`` computes with an MXU matmul.
    """
    one_hot = jax.nn.one_hot(bucket_ids, num_buckets, dtype=jnp.int32)
    # exclusive cumsum along the element axis
    csum = jnp.cumsum(one_hot, axis=0) - one_hot
    return jnp.take_along_axis(csum, bucket_ids[:, None], axis=1)[:, 0]


def scatter_to_buckets(
    x: jax.Array,
    bucket_ids: jax.Array,
    num_buckets: int,
    capacity: int,
    *,
    fill_value=None,
) -> tuple[jax.Array, jax.Array]:
    """Scatter elements into a dense (num_buckets, capacity) buffer.

    Returns (buckets, counts).  Elements beyond ``capacity`` in a bucket are
    dropped (jit-safe static shape); ``counts`` is CLIPPED to capacity so it
    reflects what was actually stored — overflow is therefore detectable as
    ``counts.sum() < x.size`` (callers raise/retry; see dist_sort docs).
    ``fill_value`` defaults to the dtype max so padded tails sort to the end.
    """
    x = jnp.asarray(x).ravel()
    if fill_value is None:
        fill_value = (
            jnp.iinfo(x.dtype).max
            if jnp.issubdtype(x.dtype, jnp.integer)
            else jnp.inf
        )
    ranks = bucket_ranks(bucket_ids, num_buckets)
    counts = jnp.minimum(bucket_counts(bucket_ids, num_buckets), capacity)
    keep = ranks < capacity
    flat_idx = jnp.where(keep, bucket_ids * capacity + ranks, num_buckets * capacity)
    out = jnp.full(num_buckets * capacity + 1, fill_value, x.dtype)
    out = out.at[flat_idx].set(x)[:-1]
    return out.reshape(num_buckets, capacity), counts


def unscatter(
    buckets: jax.Array, counts: jax.Array, total: int
) -> jax.Array:
    """Concatenate bucket prefixes (bucket order) into a flat array of ``total``.

    Because buckets are range-partitioned and individually sorted, the
    result is globally sorted — §3.1's merge-free gather.
    """
    num_buckets, capacity = buckets.shape
    offsets = jnp.cumsum(counts) - counts  # exclusive prefix
    pos_in_bucket = jnp.arange(capacity)[None, :]
    valid = pos_in_bucket < counts[:, None]
    dest = jnp.where(valid, offsets[:, None] + pos_in_bucket, total)
    out = jnp.zeros(total + 1, buckets.dtype)
    out = out.at[dest.ravel()].set(buckets.ravel())
    return out[:total]


# Exact host-side twin of the engine's integer equal-width rule — lives in
# ``repro.core.workloads`` (pure numpy, no jax) and is re-exported here so
# bucket-rule callers find both variants in one module.
from repro.core.workloads import host_bucket_ids  # noqa: E402,F401
