"""Distributed sort over a real JAX device mesh (``shard_map``).

Public API
----------
``dist_sort(x, mesh=..., axis_names=..., method=...)`` — globally sort a
sharded array.  Output contract (the TPU-native adaptation of the paper's
"array gathered at the master", DESIGN.md §2): the result stays sharded,
padded per shard with +inf/int-max, with per-shard valid counts; shard *i*
holds only keys ≤ every key of shard *i+1*, so the concatenation of valid
prefixes in shard order is the sorted array.

Methods
-------
* ``'sample'``  — balanced splitters + one fused ``all_to_all`` (the
  beyond-paper production path).
* ``'paper'``   — §3.1 equal-width range splitters + the same fused
  exchange (isolates the paper's splitter rule from its hop-by-hop
  transport so benchmarks can attribute cost).
* ``'hier'``    — two-level exchange for multi-pod meshes: one
  ``all_to_all`` *inside* each pod, then exactly one exchange *across*
  pods — the paper's "cross the optical tier once" schedule mapped onto
  mesh axes (electrical links = intra-pod axes, optical = pod axis).
* ``'valiant'`` — two-hop load-balanced routing: a deterministic
  round-robin interleave first (every device ends up with a stratified
  sample of the whole array), then the normal splitter exchange.  Kills
  the worst-case send skew of pre-sorted inputs (where shard i's whole
  payload targets device i): per-(src,dst) traffic becomes uniform, so
  ``capacity_factor≈2`` suffices where the direct route needs ≈P.
  Costs one extra all_to_all — the classic Valiant bandwidth/worst-case
  trade, and this framework's straggler-mitigation story for the sort.

All paths are jit-compatible: bucket buffers have static ``capacity``;
overflow (never hit with sampled splitters at the default factor) drops
elements and is surfaced via the returned counts, which tests check.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import partition


# Typed scalar (a bare Python int would be weak-typed int32 and overflow
# for uint32 where it feeds jnp.where directly).
_fill_value = partition.max_sentinel


def _local_splitters(local: jax.Array, num_shards: int, axis_names, oversample: int):
    """Global splitters from an all-gathered per-shard sample."""
    n_local = local.shape[0]
    s = min(n_local, max(oversample, 1))
    stride = -(-n_local // s)  # ceil: sample must span the whole shard
    sample = jax.lax.stop_gradient(local[::stride])
    gathered = sample
    for ax in axis_names:
        gathered = jax.lax.all_gather(gathered, ax, tiled=True)
    gathered = jnp.sort(gathered)
    pos = (jnp.arange(1, num_shards) * gathered.shape[0]) // num_shards
    return gathered[pos]


def _paper_splitters(local: jax.Array, num_shards: int, axis_names):
    """§3.1 equal-width ranges from the *global* min/max (psum-free: pmax)."""
    lo, hi = jnp.min(local), jnp.max(local)
    for ax in axis_names:
        lo = jax.lax.pmin(lo, ax)
        hi = jax.lax.pmax(hi, ax)
    lo_f = lo.astype(jnp.float32)
    width = (hi.astype(jnp.float32) - lo_f) / num_shards
    width = jnp.where(width > 0, width, 1.0)
    edges = lo_f + width * jnp.arange(1, num_shards, dtype=jnp.float32)
    return edges.astype(local.dtype) if jnp.issubdtype(local.dtype, jnp.integer) else edges


def _bucket_exchange(local, splitters, num_shards, capacity, axis_name):
    """Scatter into per-destination rows and run one fused all_to_all."""
    ids = partition.splitter_bucket_ids(local, splitters)
    buckets, counts = partition.scatter_to_buckets(
        local, ids, num_shards, capacity, fill_value=_fill_value(local.dtype)
    )
    # (num_shards, capacity) — row d goes to device d.
    recv = jax.lax.all_to_all(buckets, axis_name, split_axis=0, concat_axis=0, tiled=True)
    recv_counts = jax.lax.all_to_all(
        counts, axis_name, split_axis=0, concat_axis=0, tiled=True
    )
    sent = jnp.sum(counts)  # elements actually shipped (≤ local n if overflow)
    return recv, recv_counts, sent


def _finalize(recv, recv_counts, local_sort):
    """Sort the received rows' concatenation; padded tail sorts to the end."""
    merged = local_sort(recv.ravel())
    return merged, jnp.sum(recv_counts)


def dist_sort(
    x: jax.Array,
    *,
    mesh: Mesh,
    axis_names: Sequence[str] = ("data",),
    method: str = "sample",
    capacity_factor: float = 2.0,
    oversample: int = 64,
    local_sort=jnp.sort,
):
    """Globally sort ``x`` (sharded on its leading axis over ``axis_names``).

    Returns ``(values, counts)``: ``values`` is (devices * capacity,)
    globally sharded, each shard sorted and padded at its tail;
    ``counts`` is (devices,) the per-shard valid lengths.  Dropped-element
    detection: ``counts.sum() == x.size`` iff no capacity overflow.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    num_shards = 1
    for ax in axis_names:
        num_shards *= sizes[ax]
    n = x.shape[0]
    if n % num_shards:
        raise ValueError(f"n={n} not divisible by shard count {num_shards}")
    n_local = n // num_shards
    capacity = int(capacity_factor * -(-n_local // num_shards))
    capacity += (-capacity) % 8

    if method in ("sample", "paper", "valiant"):
        impl = functools.partial(
            _flat_impl,
            num_shards=num_shards,
            capacity=capacity,
            method=method,
            oversample=oversample,
            axis_names=tuple(axis_names),
            local_sort=local_sort,
        )
        spec = P(tuple(axis_names))
    elif method == "hier":
        if len(axis_names) < 2:
            raise ValueError("hier method needs (outer, inner) axes, e.g. ('pod','data')")
        impl = functools.partial(
            _hier_impl,
            axis_names=tuple(axis_names),
            sizes=tuple(sizes[a] for a in axis_names),
            capacity_factor=capacity_factor,
            oversample=oversample,
            local_sort=local_sort,
        )
        spec = P(tuple(axis_names))
    else:
        raise ValueError(f"unknown method {method!r}")

    fn = compat.shard_map(
        impl, mesh=mesh, in_specs=(spec,), out_specs=(spec, spec)
    )
    return fn(x)


def _flat_impl(local, *, num_shards, capacity, method, oversample, axis_names, local_sort):
    local = local.ravel()
    # Exchange runs over a single logical axis: if the shard spans several
    # mesh axes, they act as one flattened axis for all_to_all.
    ax = axis_names if len(axis_names) > 1 else axis_names[0]
    if method == "valiant":
        # hop 1: round-robin interleave — device d receives a stratified
        # 1/P sample from every source, destroying any value/order skew.
        n_local = local.shape[0]
        per = n_local // num_shards
        head = jax.lax.all_to_all(
            local[: per * num_shards].reshape(num_shards, per),
            ax, split_axis=0, concat_axis=0, tiled=True,
        ).ravel()
        # indivisible tail stays local (counted, never dropped)
        local = jnp.concatenate([head, local[per * num_shards :]])
    if method == "paper":
        splitters = _paper_splitters(local, num_shards, axis_names)
    else:
        splitters = _local_splitters(local, num_shards, axis_names, oversample)
    recv, recv_counts, _ = _bucket_exchange(local, splitters, num_shards, capacity, ax)
    merged, count = _finalize(recv, recv_counts, local_sort)
    return merged, count[None]


def _hier_impl(local, *, axis_names, sizes, capacity_factor, oversample, local_sort):
    """Two-level exchange: global splitters, but traffic crosses the slow
    (outer/pod) axis exactly once, then fans out on the fast inner axis.

    Stage 1 (optical, once): bucket by destination *pod* and all_to_all over
    the pod axis.  Stage 2 (electrical): bucket by destination device within
    the pod and all_to_all over the inner axis.  Equivalent result to the
    flat exchange; traffic on the slow tier is minimal and contiguous.
    """
    outer_ax, inner_ax = axis_names[0], axis_names[1:]
    outer_n = sizes[0]
    inner_n = 1
    for s in sizes[1:]:
        inner_n *= s
    num_shards = outer_n * inner_n
    local = local.ravel()
    n_local = local.shape[0]

    splitters = _local_splitters(local, num_shards, axis_names, oversample)
    # ---- stage 1: route to the destination pod (outer axis), one crossing.
    pod_splitters = splitters[inner_n - 1 :: inner_n]  # every inner_n-th → pod edges
    cap1 = int(capacity_factor * -(-n_local // outer_n))
    cap1 += (-cap1) % 8
    recv1, cnt1, _ = _bucket_exchange(local, pod_splitters, outer_n, cap1, outer_ax)
    # Compact: received rows concatenated; invalid slots are fill (sort last).
    stage1 = recv1.ravel()
    valid1 = jnp.sum(cnt1)

    # ---- stage 2: inside the pod, route to the destination device.
    my_pod = jax.lax.axis_index(outer_ax)
    inner_splitters = jax.lax.dynamic_slice_in_dim(
        jnp.concatenate([splitters, splitters[-1:]]), my_pod * inner_n, inner_n
    )[: inner_n - 1]
    cap2 = int(capacity_factor * -(-stage1.shape[0] // inner_n))
    cap2 += (-cap2) % 8
    inner = inner_ax if len(inner_ax) > 1 else inner_ax[0]
    ids = partition.splitter_bucket_ids(stage1, inner_splitters)
    # Fill slots from stage 1 carry the dtype max; they bucket to the last
    # device — mask them to an overflow row instead so counts stay exact.
    pos = jnp.arange(stage1.shape[0])
    is_valid = pos < 0  # placeholder; recompute validity via counts layout
    # stage1 layout: outer_n rows of cap1; row r has cnt1[r] valid entries.
    row, col = jnp.divmod(pos, cap1)
    is_valid = col < cnt1[row]
    ids = jnp.where(is_valid, ids, inner_n)  # inner_n = drop row
    buckets, counts = partition.scatter_to_buckets(
        jnp.where(is_valid, stage1, _fill_value(stage1.dtype)),
        ids,
        inner_n + 1,
        cap2,
        fill_value=_fill_value(stage1.dtype),
    )
    buckets, counts = buckets[:inner_n], counts[:inner_n]
    recv2 = jax.lax.all_to_all(buckets, inner, split_axis=0, concat_axis=0, tiled=True)
    cnt2 = jax.lax.all_to_all(counts, inner, split_axis=0, concat_axis=0, tiled=True)
    merged, count = _finalize(recv2, cnt2, local_sort)
    del valid1
    return merged, count[None]


def host_check_globally_sorted(values, counts) -> bool:
    """Host-side validation of the output contract."""
    import numpy as np

    values = np.asarray(values)
    counts = np.asarray(counts).ravel()
    shards = np.split(values, counts.size)
    prev_max = None
    for shard, c in zip(shards, counts):
        valid = np.sort(shard)[: int(c)]  # shard is sorted with fill at tail
        if not np.all(valid[:-1] <= valid[1:]):
            return False
        if prev_max is not None and valid.size and prev_max > valid[0]:
            return False
        if valid.size:
            prev_max = valid[-1]
    return True
