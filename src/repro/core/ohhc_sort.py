"""Paper-faithful parallel Quick Sort on the OHHC (§3) + instrumentation.

Three execution paths, all sharing the same topology/schedule/partition
code so the counters and the data path can never diverge:

* ``ohhc_sort_sim``  — jit-able simulated-processor path: the ``total_procs``
  processors are axis 0 of a dense (P, capacity) bucket buffer; local sorts
  are vmapped (bitonic kernel or ``jnp.sort``).  Used by tests and the
  small benchmarks.
* ``ohhc_sort_host`` — numpy orchestration at full paper sizes (10–60 MB):
  exact ragged buckets, per-bucket wall-clock sort timing (feeds the
  relative-speedup model: "time of the last thread finish" = max bucket
  sort time + modelled communication).
* ``repro.core.dist_sort`` — the real ``shard_map`` path over a device mesh
  (separate module).

Also here: the instrumented sequential Quick Sort reproducing the paper's
Figs 6.20–6.24 counters (recursion calls, iterations, swaps) and the
store-and-forward communication cost model (Theorem 6's ``t·(2·d_h+3)``
delay emerges as the critical path of the schedule for one chunk).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partition
from repro.core.schedule import AccumulationSchedule, payload_bytes_per_round
from repro.core.topology import OHHCTopology


# --------------------------------------------------------------------------
# Communication cost model (store-and-forward, Theorem 6 semantics)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Per-link-class bandwidth/latency.  Defaults ≈ TPU v5e ICI vs inter-pod.

    The paper's conclusion laments that "the difference in the speed of the
    electrical and optical connections ... was not taken into consideration"
    — we model it explicitly.
    """

    electrical_gbps: float = 50.0  # intra-pod ICI, GB/s per link
    optical_gbps: float = 25.0  # inter-pod, GB/s per link
    alpha_us: float = 1.0  # per-message latency, microseconds

    def round_time_s(self, link: str, max_msg_bytes: int) -> float:
        bw = self.electrical_gbps if link == "electrical" else self.optical_gbps
        return self.alpha_us * 1e-6 + max_msg_bytes / (bw * 1e9)


def model_comm_time_s(
    schedule: AccumulationSchedule,
    chunk_sizes: "list[int] | np.ndarray",
    link_model: LinkModel = LinkModel(),
    itemsize: int = 4,
    roundtrip: bool = True,
) -> float:
    """Critical-path communication time: each round costs its largest message."""
    rounds = payload_bytes_per_round(schedule, list(chunk_sizes), itemsize)
    t = sum(link_model.round_time_s(r["link"], r["max_msg_bytes"]) for r in rounds)
    return 2.0 * t if roundtrip else t


# --------------------------------------------------------------------------
# jit-able simulated path
# --------------------------------------------------------------------------
def ohhc_sort_sim(
    x: jax.Array,
    topo: OHHCTopology,
    *,
    capacity: int | None = None,
    method: str = "paper",
    local_sort: Callable[[jax.Array], jax.Array] | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Sort ``x`` with the paper's algorithm on a simulated processor axis.

    Returns ``(sorted_x, bucket_counts)``.  ``method='paper'`` uses the §3.1
    equal-width ranges; ``method='sampled'`` uses balanced splitters
    (beyond-paper).  ``capacity`` is the static per-bucket buffer size;
    defaults to ``2 * ceil(n / P)`` rounded up to a multiple of 8 (tests
    assert no overflow for their inputs).
    """
    x = jnp.asarray(x).ravel()
    n = x.shape[0]
    P = topo.total_procs
    if capacity is None:
        capacity = partition.default_capacity(n, P)
    if method == "paper":
        ids = partition.paper_bucket_ids(x, P)
    elif method == "sampled":
        spl = partition.sampled_splitters(x, P)
        ids = partition.splitter_bucket_ids(x, spl)
    else:
        raise ValueError(f"unknown method {method!r}")
    buckets, counts = partition.scatter_to_buckets(x, ids, P, capacity)
    if local_sort is None:
        local_sort = jnp.sort
    buckets = jax.vmap(local_sort)(buckets)
    out = partition.unscatter(buckets, counts, n)
    return out, counts


# --------------------------------------------------------------------------
# Host (numpy) path at paper scale, with per-bucket timing
# --------------------------------------------------------------------------
@dataclasses.dataclass
class HostSortResult:
    sorted_array: np.ndarray
    bucket_sizes: np.ndarray  # (total_procs,)
    local_sort_times_s: np.ndarray  # (total_procs,)
    partition_time_s: float
    comm_model_time_s: float
    paper_steps: int
    tree_sends: int
    critical_rounds: int

    @property
    def t_parallel_model_s(self) -> float:
        """Paper's 'last thread finish' analogue: slowest local sort + comm."""
        return float(self.local_sort_times_s.max()) + self.comm_model_time_s


def ohhc_sort_host(
    x: np.ndarray,
    topo: OHHCTopology,
    *,
    method: str = "paper",
    link_model: LinkModel = LinkModel(),
) -> HostSortResult:
    """Full-size numpy execution of the algorithm with exact ragged buckets."""
    x = np.asarray(x).ravel()
    P = topo.total_procs
    t0 = time.perf_counter()
    if method == "paper":
        lo, hi = x.min(), x.max()
        width = (float(hi) - float(lo)) / P
        if width <= 0:
            ids = np.zeros(x.shape, np.int64)
        else:
            # float64 difference: narrow signed dtypes (int8 spanning the
            # negative range) would wrap under native-dtype subtraction.
            ids = np.clip(
                ((x.astype(np.float64) - float(lo)) / width).astype(np.int64),
                0, P - 1,
            )
    elif method == "sampled":
        s = min(x.size, 32 * P)
        sample = np.sort(x[:: -(-x.size // s)])
        splitters = sample[(np.arange(1, P) * sample.size) // P]
        ids = np.searchsorted(splitters, x, side="right")
    else:
        raise ValueError(method)
    order = np.argsort(ids, kind="stable")
    sizes = np.bincount(ids, minlength=P)
    bounds = np.concatenate([[0], np.cumsum(sizes)])
    gathered = x[order]
    t_partition = time.perf_counter() - t0

    times = np.zeros(P)
    out = np.empty_like(x)
    for p in range(P):
        seg = gathered[bounds[p] : bounds[p + 1]]
        t1 = time.perf_counter()
        out[bounds[p] : bounds[p + 1]] = np.sort(seg, kind="quicksort")
        times[p] = time.perf_counter() - t1

    sched = AccumulationSchedule.build(topo)
    comm = model_comm_time_s(sched, sizes, link_model, itemsize=x.dtype.itemsize)
    return HostSortResult(
        sorted_array=out,
        bucket_sizes=sizes,
        local_sort_times_s=times,
        partition_time_s=t_partition,
        comm_model_time_s=comm,
        paper_steps=sched.paper_step_count(),
        tree_sends=sched.roundtrip_send_count(),
        critical_rounds=sched.critical_path_rounds(),
    )


# --------------------------------------------------------------------------
# Instrumented sequential Quick Sort (Figs 6.20–6.24 counters)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class QuickSortCounters:
    recursion_calls: int = 0
    iterations: int = 0  # element visits during partitioning ("comparisons")
    swaps: int = 0

    def __iadd__(self, o: "QuickSortCounters"):
        self.recursion_calls += o.recursion_calls
        self.iterations += o.iterations
        self.swaps += o.swaps
        return self


def quicksort_counters(x: np.ndarray, *, pivot: str = "middle") -> QuickSortCounters:
    """Count recursion calls / iterations / swaps of Quick Sort.

    Middle-element pivot (the paper's sequential runs are *faster* on
    sorted/reverse-sorted inputs — Fig 6.1 — which rules out first/last
    pivots).  Iterations: m−1 element visits per partition of a length-m
    segment.  Swaps: **Hoare pair-exchange semantics** — one swap per
    element initially in the left zone that belongs right (each pairs with
    a misplaced right element); an already-sorted segment costs 0 swaps,
    reproducing the paper's Fig 6.22 sorted≪random gap.
    Segment loop is Python-level; use reduced sizes for quick runs.
    """
    x = np.asarray(x).copy()
    c = QuickSortCounters()
    stack = [(0, x.size)]
    while stack:
        lo, hi = stack.pop()
        m = hi - lo
        if m <= 1:
            continue
        c.recursion_calls += 1
        seg = x[lo:hi]
        if pivot == "middle":
            pi = m // 2
        elif pivot == "last":
            pi = m - 1
        else:
            raise ValueError(pivot)
        pv = seg[pi]
        c.iterations += m - 1
        less = seg < pv
        n_less = int(less.sum())
        # Hoare semantics: each element sitting in the final left zone that
        # is NOT < pivot must be exchanged with a misplaced right element.
        c.swaps += int((~less[:n_less]).sum())
        # Stable reconstruction of the partition result (counts are what we
        # need; actual element order within halves doesn't change counts of
        # subsequent *middle*-pivot partitions in expectation, but we keep
        # the true partition layout for exactness).
        geq = ~less
        geq[pi] = False
        x[lo : lo + n_less] = seg[less]
        x[lo + n_less] = pv
        x[lo + n_less + 1 : hi] = seg[geq]
        stack.append((lo, lo + n_less))
        stack.append((lo + n_less + 1, hi))
    return c


def parallel_quicksort_counters(
    x: np.ndarray, topo: OHHCTopology, *, method: str = "paper"
) -> QuickSortCounters:
    """Counters summed over all per-processor bucket sorts (Figs 6.20–6.22)."""
    x = np.asarray(x).ravel()
    P = topo.total_procs
    if method == "paper":
        lo, hi = x.min(), x.max()
        width = (float(hi) - float(lo)) / P
        ids = (
            np.zeros(x.shape, np.int64)
            if width <= 0
            else np.clip(
                ((x.astype(np.float64) - float(lo)) / width).astype(np.int64),
                0, P - 1,
            )
        )
    else:
        s = min(x.size, 32 * P)
        sample = np.sort(x[:: -(-x.size // s)])
        splitters = sample[(np.arange(1, P) * sample.size) // P]
        ids = np.searchsorted(splitters, x, side="right")
    order = np.argsort(ids, kind="stable")
    sizes = np.bincount(ids, minlength=P)
    bounds = np.concatenate([[0], np.cumsum(sizes)])
    gathered = x[order]
    total = QuickSortCounters()
    for p in range(P):
        total += quicksort_counters(gathered[bounds[p] : bounds[p + 1]])
    return total


def bitonic_counters(n: int) -> dict:
    """Closed-form compare counts for the TPU-native bitonic local sort."""
    k = max(int(np.ceil(np.log2(max(n, 1)))), 0)
    stages = k * (k + 1) // 2
    return {
        "stages": stages,
        "comparisons": stages * (1 << k) // 2,
        "padded_n": 1 << k,
    }
