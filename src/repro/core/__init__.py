"""Core: the paper's contribution — parallel Quick Sort on the OHHC.

Modules: topology (OHHC graph), schedule (3-phase accumulation + Theorem-3
accounting), partition (Array Division Procedure + balanced splitters),
ohhc_sort (paper-faithful sort + counters + cost model), sample_sort
(beyond-paper models), dist_sort (shard_map mesh implementation), engine
(the unified autotuned dispatch layer over all three paths, DESIGN.md §4),
workloads (host arithmetic behind the engine's top-k / pytree pairs /
streaming-merge operations, DESIGN.md §12).
"""

from repro.core.topology import OHHCTopology, table_1_1, HHC_SIZE
from repro.core.schedule import AccumulationSchedule, payload_bytes_per_round
from repro.core.partition import (
    default_capacity,
    pack_segments,
    paper_bucket_ids,
    sampled_splitters,
    splitter_bucket_ids,
    bucket_counts,
    bucket_ranks,
    scatter_to_buckets,
    unpack_segments,
    unscatter,
)
from repro.core.ohhc_sort import (
    LinkModel,
    ohhc_sort_sim,
    ohhc_sort_host,
    quicksort_counters,
    parallel_quicksort_counters,
    bitonic_counters,
    model_comm_time_s,
)
from repro.core.dist_sort import dist_sort, host_check_globally_sorted
from repro.core.workloads import (
    WORKLOAD_OPS,
    TopKTooLarge,
    host_bucket_ids,
    host_top_k,
    merge_sorted_arrays,
    topk_cut,
)
from repro.core.engine import (
    BITONIC_METHODS,
    ROW_BACKENDS,
    SEGMENT_BITONIC_MAX,
    InputStats,
    SortEngine,
    SortPlan,
    autotune_capacity,
    choose_batch_plan,
    choose_plan,
    choose_row_backend,
    estimate_batch_stats,
    estimate_stats,
    x64_enabled,
)

__all__ = [
    "BITONIC_METHODS",
    "ROW_BACKENDS",
    "SEGMENT_BITONIC_MAX",
    "InputStats",
    "SortEngine",
    "SortPlan",
    "autotune_capacity",
    "choose_batch_plan",
    "choose_plan",
    "choose_row_backend",
    "estimate_batch_stats",
    "estimate_stats",
    "x64_enabled",
    "OHHCTopology",
    "table_1_1",
    "HHC_SIZE",
    "AccumulationSchedule",
    "payload_bytes_per_round",
    "default_capacity",
    "pack_segments",
    "unpack_segments",
    "paper_bucket_ids",
    "sampled_splitters",
    "splitter_bucket_ids",
    "bucket_counts",
    "bucket_ranks",
    "scatter_to_buckets",
    "unscatter",
    "LinkModel",
    "ohhc_sort_sim",
    "ohhc_sort_host",
    "quicksort_counters",
    "parallel_quicksort_counters",
    "bitonic_counters",
    "model_comm_time_s",
    "dist_sort",
    "host_check_globally_sorted",
    "WORKLOAD_OPS",
    "TopKTooLarge",
    "host_bucket_ids",
    "host_top_k",
    "merge_sorted_arrays",
    "topk_cut",
]
