"""Unified autotuned sort engine — the single entry point over the three
execution paths (DESIGN.md §4).

The repo has three faithful implementations of the paper's parallel Quick
Sort — ``ohhc_sort_sim`` (jit/vmap simulated processors), ``ohhc_sort_host``
(paper-scale numpy with the Theorem-6 comm model) and ``dist_sort``
(``shard_map`` over a real device mesh) — each with its own method knob
(``paper``/``sampled``/``sample``/``hier``/``valiant``) and a bucket
``capacity`` the caller had to guess.  ``SortEngine`` removes the guessing:

1. **Stats inspection** (``estimate_stats``): a strided ≤1 k sample yields
   ``sortedness`` (asc-pair minus desc-pair fraction), ``skew`` (max/mean of
   an equal-width histogram — the quantity that breaks the paper's Array
   Division Procedure), the top-duplicate fraction, and the *measured* max
   bucket fraction under each splitter rule.  The labels map onto the
   paper's §5 input taxonomy (random / sorted / reversed / local) plus the
   beyond-paper duplicate-heavy class.

2. **Dispatch** (``choose_plan``): stats × topology → execution path and
   method.  The full decision table is DESIGN.md §4; the shape is
   *mesh → dist (hier > valiant > sampled > paper), huge or heavily skewed
   → host (exact ragged buckets), else → sim*.

3. **Capacity autotune** (``autotune_capacity``): instead of the fixed
   ``2·ceil(n/P)`` heuristic, capacity comes from the measured max bucket
   fraction plus a 3σ binomial sampling-error term and a safety margin,
   clamped below by the legacy heuristic (which is also the deterministic
   answer for balanced inputs, keeping the jit cache warm) and quantized to
   powers of two above it.  ``sort`` verifies the returned counts and
   escalates capacity ×2 on the (rare) overflow, so the answer is always
   exact.

4. **Warm jit cache**: compiled executables are keyed on
   ``(pow2 size bucket, capacity, method, dtype, P)``; inputs are padded to
   the bucket and the valid length is passed as a *traced* scalar, so
   repeated traffic of nearby sizes never recompiles.  ``trace_count``
   exposes actual retraces for tests and monitoring.

Batched entry points: ``sort_segments`` fuses many variable-length arrays
into ONE padded ``(B, Lbucket)`` vmapped device call (worst-row stats and
capacity measured in one vectorized pass — the device-side foundation of
the ``repro.serve.sortd`` micro-batching service, DESIGN.md §8);
``sort_many`` is its list-of-arrays wrapper; ``sort_pairs`` is the
key/payload sort (bitonic pair kernel) behind
``repro.serve.engine.ServeEngine``'s length-ordering hot path.
"""

from __future__ import annotations

import dataclasses
import math
import os
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partition, workloads
from repro.core.ohhc_sort import ohhc_sort_host
from repro.core.topology import OHHCTopology
from repro.core.workloads import TopKTooLarge
from repro.kernels import batched as batched_kernels
from repro.kernels import ops

# Granularity cap for stats histograms: coarser than P only ever
# *over*-estimates the max bucket fraction (refining buckets can't raise it).
_MAX_STAT_BUCKETS = 256

# Largest row bucket the segmented batch path sorts with the direct
# sentinel-padded bitonic row kernel instead of the P-way bucket machinery
# (see choose_batch_plan).
SEGMENT_BITONIC_MAX = 1 << 13

# The row-sort backends the bitonic segment path can run on (DESIGN.md §8):
# ``vmap`` is the vmapped XLA-level sort, ``pallas`` the fused batched
# Pallas kernel (``kernels/batched.py``, sentinel-fill + sort + validity
# mask in ONE pallas_call with the grid over the batch axis), ``pallas2op``
# the same kernel with the NICE 2-op compare-exchange stage.  Each backend
# is a distinct plan method so the jit cache, ``SortPlan.reason`` and the
# sortd metrics all name the executed kernel.
ROW_BACKENDS = ("vmap", "pallas", "pallas2op")
_BACKEND_METHODS = {
    "vmap": "bitonic",
    "pallas": "bitonic_pallas",
    "pallas2op": "bitonic2op",
}
# Every method string that means "direct sentinel-padded row sort" — no
# capacity, no overflow (the complement of the bucket-path methods).
BITONIC_METHODS = tuple(_BACKEND_METHODS.values())

# One measured head-to-head per (row bucket, dtype, probe batch) per
# process — shared across engines so a fleet of workers probes once, like
# a jit cache.
_ROW_BACKEND_CACHE: dict[tuple[int, str, int], tuple[str, str]] = {}

# The probe batch is bucketed to the serving batch (pow2, clamped) because
# relative backend cost is batch-dependent: the interpreted Pallas grid
# walks rows sequentially while the vmapped XLA sort amortizes across the
# whole batch, so a B=8 probe mispredicts a B=64 serve.
_PROBE_BATCH_MIN, _PROBE_BATCH_MAX = 8, 64


def _probe_batch_for(batch_hint: int) -> int:
    b = max(int(batch_hint), 1)
    return min(max(1 << (b - 1).bit_length(), _PROBE_BATCH_MIN), _PROBE_BATCH_MAX)


def choose_row_backend(
    padded_n: int,
    dtype,
    *,
    local_sort: Callable | None = None,
    batch_hint: int = 8,
    probe_batch: "int | None" = None,
    repeats: int = 3,
) -> tuple[str, str]:
    """Autotuned row-sort backend for bitonic segment rows: measured
    head-to-head of the vmapped XLA path vs the fused Pallas kernel
    (both variants on integer keys), at plan time, on this host's actual
    execution mode (interpret on CPU, compiled Mosaic on TPU).

    The probe runs at the serving batch size (``batch_hint`` bucketed by
    :func:`_probe_batch_for`; ``probe_batch`` overrides it exactly) —
    backend ranking flips with batch, so probing a fixed tiny batch would
    select a backend the real batch then loses with.

    Returns ``(backend, detail)`` where ``detail`` is the human-readable
    probe record that lands in ``SortPlan.reason``.  Cached per
    ``(padded_n, dtype, probe batch)`` for the process; ``REPRO_ROW_BACKEND``
    forces a backend (``vmap`` / ``pallas`` / ``pallas2op``) and skips the
    probe — the deterministic knob tests, benchmarks and operators use.
    """
    forced = os.environ.get("REPRO_ROW_BACKEND", "").strip().lower()
    if forced:
        if forced not in ROW_BACKENDS:
            raise ValueError(
                f"REPRO_ROW_BACKEND={forced!r} not in {ROW_BACKENDS}"
            )
        return forced, f"row_backend={forced} (forced via REPRO_ROW_BACKEND)"
    if probe_batch is None:
        probe_batch = _probe_batch_for(batch_hint)
    np_dtype = np.dtype(dtype)
    key = (padded_n, str(np_dtype), probe_batch)
    hit = _ROW_BACKEND_CACHE.get(key)
    if hit is not None:
        return hit
    interpret = ops._auto_interpret(None)
    rng = np.random.default_rng(padded_n)
    if np.issubdtype(np_dtype, np.integer):
        info = np.iinfo(np_dtype)
        x = rng.integers(
            info.min, info.max, (probe_batch, padded_n), dtype=np_dtype
        )
    else:
        x = rng.normal(size=(probe_batch, padded_n)).astype(np_dtype)
    xj = jnp.asarray(x)
    lens = jnp.full((probe_batch,), padded_n, jnp.int32)
    row_sort = local_sort if local_sort is not None else jnp.sort
    candidates: dict[str, Callable] = {
        "vmap": jax.jit(jax.vmap(row_sort)),
        "pallas": lambda a: batched_kernels.batched_row_sort(
            a, lens, method="bitonic", interpret=interpret
        ),
    }
    if np.issubdtype(np_dtype, np.integer):
        candidates["pallas2op"] = lambda a: batched_kernels.batched_row_sort(
            a, lens, method="bitonic2op", interpret=interpret
        )
    timings: dict[str, float] = {}
    for name, fn in candidates.items():
        fn(xj).block_until_ready()  # warm: trace + compile outside the clock
        best = math.inf
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn(xj).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        timings[name] = best
    backend = min(timings, key=timings.get)  # type: ignore[arg-type]
    detail = "row_backend=%s (autotuned @B%d: %s)" % (
        backend,
        probe_batch,
        ", ".join(f"{k} {v * 1e3:.2f}ms" for k, v in timings.items()),
    )
    _ROW_BACKEND_CACHE[key] = (backend, detail)
    return backend, detail


def x64_enabled() -> bool:
    """True when jax will preserve 64-bit dtypes end to end.

    With x64 off (the default), ``jnp.asarray`` silently downcasts
    int64/uint64/float64 keys to their 32-bit twins — so every jit path
    would sort *different values* than the caller handed in.  Dispatch
    (``choose_plan``) and the verify grid's pruning rules both consult this.
    """
    return bool(jax.config.jax_enable_x64)


# --------------------------------------------------------------------------
# Input statistics
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InputStats:
    """Cheap sampled statistics of one sort request."""

    n: int
    dtype: str
    sample_size: int
    sortedness: float  # +1 ascending … −1 descending, ties neutral
    skew: float  # max/mean of the equal-width histogram (1.0 = balanced)
    dup_top_frac: float  # mass of the most frequent sampled value
    f_max_paper: float  # measured max bucket fraction, equal-width rule
    f_max_sampled: float  # measured max bucket fraction, sampled splitters
    num_buckets: int  # histogram granularity the f_max fields used

    @property
    def label(self) -> str:
        """Best-guess class in the paper's §5 taxonomy (+ 'dupes')."""
        if self.sortedness > 0.8:
            return "sorted"
        if self.sortedness < -0.8:
            return "reversed"
        if self.dup_top_frac > 0.25:
            return "dupes"
        if self.skew > 4.0:
            return "local"
        return "random"

    @property
    def skewed(self) -> bool:
        """True when equal-width ranges would overload some processor."""
        return self.skew > 2.0 or self.dup_top_frac > 0.25


def estimate_stats(
    x, *, num_buckets: int = 64, sample_size: int = 2048
) -> InputStats:
    """Measure ``InputStats`` from an evenly spread sample (host, O(sample)).

    Exactly ``min(n, sample_size)`` linspace-positioned elements: the sample
    spans the whole array (order statistics like sortedness stay meaningful
    on sorted inputs) and its size never halves across nearby ``n`` — a
    stable ``s`` keeps the 3σ term in :func:`autotune_capacity`, and hence
    the chosen capacity and jit-cache key, stable across a shape bucket.
    """
    x = np.asarray(x).ravel()
    n = x.size
    if n == 0:
        return InputStats(0, str(x.dtype), 0, 1.0, 1.0, 0.0, 0.0, 0.0, num_buckets)
    s = int(min(n, sample_size))
    idx = (np.arange(s, dtype=np.int64) * n) // s
    sample = x[idx].astype(np.float64)
    diffs = np.diff(sample)
    sortedness = (
        float(np.mean(diffs > 0) - np.mean(diffs < 0)) if diffs.size else 1.0
    )
    _, uniq_counts = np.unique(sample, return_counts=True)
    dup_top_frac = float(uniq_counts.max()) / s

    B = int(min(num_buckets, _MAX_STAT_BUCKETS))
    lo, hi = sample.min(), sample.max()
    width = (hi - lo) / B
    if width <= 0:
        ids = np.zeros(s, np.int64)
    else:
        ids = np.clip(((sample - lo) / width).astype(np.int64), 0, B - 1)
    counts = np.bincount(ids, minlength=B)
    f_max_paper = float(counts.max()) / s
    skew = f_max_paper * B  # max / (s/B)

    srt = np.sort(sample)
    splitters = srt[(np.arange(1, B) * s) // B]
    ids2 = np.searchsorted(splitters, sample, side="right")
    f_max_sampled = float(np.bincount(ids2, minlength=B).max()) / s

    return InputStats(
        n=n,
        dtype=str(x.dtype),
        sample_size=s,
        sortedness=sortedness,
        skew=float(skew),
        dup_top_frac=dup_top_frac,
        f_max_paper=f_max_paper,
        f_max_sampled=f_max_sampled,
        num_buckets=B,
    )


def estimate_batch_stats(
    padded: np.ndarray,
    seg_lens,
    *,
    num_buckets: int = 64,
    sample_size: int = 256,
) -> InputStats:
    """Worst-row ``InputStats`` for a packed ``(B, row_len)`` segment batch.

    One fused device call (``SortEngine.sort_segments``) must pick a single
    capacity for every row, so the quantity that matters is the *worst row's*
    max bucket fraction — a blended whole-batch histogram would wash a
    single pathological row out of the estimate and buy an overflow retry
    per flush.  Everything here is vectorized numpy over a strided
    ``(B, s)`` per-row sample (no per-row Python loop — the point of the
    segmented path):

    * per-row equal-width bucket counts via one offset ``bincount`` →
      ``f_max_paper``.  The sample is bucketed against each row's **true**
      min/max (one vectorized masked pass over the packed matrix — we paid
      for the pack already), not the sample's own range: a clustered row
      with tail outliers (the paper's "local" class) has a true range the
      sample misses, and the kernel's equal-width rule uses the true range —
      sample-range bucketing underestimates its hot bucket by >10×;
    * per-row top-duplicate mass via run lengths of the sorted sample
      (``dup_top_frac``); under sampled (quantile) splitters only
      indivisible duplicate mass can overload a bucket, so
      ``f_max_sampled = max(1/num_buckets, dup_top_frac)``;
    * ``sortedness`` is the mean over rows (label/diagnostics only — batch
      method choice keys off skew and duplicates).

    Per-row fractions are scaled by ``len/row_len`` before the worst-row
    reduction: capacity is measured in *elements* of a padded row, and a
    short row's hot bucket holds at most its own length — without the
    scaling one 1-element row (f̂ = 1.0 by definition) would size every
    batch buffer at the full row length.  Rows of length 0 are masked out
    of every reduction.
    """
    padded = np.asarray(padded)
    lens = np.asarray(seg_lens, dtype=np.int64).ravel()
    B, row_len = padded.shape
    total = int(lens.sum())
    dtype = str(padded.dtype)
    nb = int(min(num_buckets, _MAX_STAT_BUCKETS))
    live = lens > 0
    if total == 0 or not live.any():
        return InputStats(total, dtype, 0, 1.0, 1.0, 0.0, 0.0, 0.0, nb)
    s = int(min(row_len, sample_size))
    # Strided per-row sample over each row's own valid prefix: index
    # (j·len)//s < len for every len ≥ 1, so no pad cell is ever sampled
    # from a live row.
    idx = (np.arange(s)[None, :] * lens[:, None]) // s
    samp = padded[np.arange(B)[:, None], np.clip(idx, 0, row_len - 1)]
    samp = samp.astype(np.float64)

    # True per-row range over the valid prefix (pad cells masked out): the
    # kernel's equal-width buckets use it, so the estimate must too.
    pos = np.arange(row_len)[None, :]
    valid = pos < lens[:, None]
    pf = padded.astype(np.float64)
    lo = np.where(valid, pf, np.inf).min(axis=1)
    hi = np.where(valid, pf, -np.inf).max(axis=1)
    lo = np.where(live, lo, 0.0)
    width = np.where(live, (hi - lo) / nb, 1.0)
    width = np.where(width > 0, width, 1.0)
    # clip in float BEFORE the integer cast: dead rows sample their fill
    # value (dtype max / inf), which overflows a float→int64 cast
    ids = np.clip((samp - lo[:, None]) / width[:, None], 0, nb - 1).astype(np.int64)
    counts = np.bincount(
        (ids + np.arange(B)[:, None] * nb).ravel(), minlength=B * nb
    ).reshape(B, nb)
    # elements-of-a-padded-row units: f̂_row · (len/row_len)
    row_scale = lens / float(row_len)
    f_rows = counts.max(axis=1) / s * row_scale
    f_max_paper = float(f_rows[live].max())

    srt = np.sort(samp, axis=1)
    change = np.ones((B, s), bool)
    change[:, 1:] = srt[:, 1:] != srt[:, :-1]
    run_ids = np.cumsum(change, axis=1) - 1  # < s per row
    run_counts = np.bincount(
        (run_ids + np.arange(B)[:, None] * s).ravel(), minlength=B * s
    ).reshape(B, s)
    dup_rows = run_counts.max(axis=1) / s * row_scale
    dup_top_frac = float(dup_rows[live].max())

    diffs = np.diff(samp, axis=1)
    if diffs.shape[1]:
        per_row = np.mean(diffs > 0, axis=1) - np.mean(diffs < 0, axis=1)
        sortedness = float(per_row[live].mean())
    else:
        sortedness = 1.0
    return InputStats(
        n=total,
        dtype=dtype,
        sample_size=int(live.sum()) * s,
        sortedness=sortedness,
        skew=f_max_paper * nb,
        dup_top_frac=dup_top_frac,
        f_max_paper=f_max_paper,
        f_max_sampled=max(1.0 / nb, dup_top_frac),
        num_buckets=nb,
    )


# --------------------------------------------------------------------------
# Dispatch policy (pure — DESIGN.md §4 decision table)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SortPlan:
    path: str  # 'sim' | 'host' | 'dist'
    method: str  # sim/host: 'paper'|'sampled'; dist: +'hier'|'valiant'|'sample'
    capacity: int | None  # sim only: static per-bucket buffer length
    padded_n: int | None  # sim only: pow2 shape bucket the input pads to
    reason: str
    # dist only: simulated one-way gather time over the OHHC link graph
    # (repro.net, DESIGN.md §6) for this request's size — the measured-
    # timeline comm-cost estimate attached to dispatch decisions.
    comm_sim_s: float | None = None
    # Degraded serving (DESIGN.md §11): the active FaultScenario's name, and
    # — when the degraded gather is still possible — the netsim-predicted
    # gather slowdown (degraded/healthy, barrier accounting).  A fault that
    # makes the gather impossible rewrites the whole plan onto the healthy
    # host path instead and leaves fault_slowdown None.
    fault: str | None = None
    fault_slowdown: float | None = None


def autotune_capacity(
    stats: InputStats,
    method: str,
    num_buckets: int,
    padded_n: int,
    *,
    margin: float = 1.25,
) -> int:
    """Bucket capacity from the *measured* overflow model.

    Target load is ``f̂·margin·padded_n`` with ``f̂`` the measured max
    bucket fraction of the sample (for ``n ≤ sample_size`` the sample is
    the whole array, so f̂ is exact; beyond that the ×1.25 margin covers
    ~2σ of binomial sampling error for any f̂ the quantization doesn't
    already absorb — and ``SortEngine.sort``'s overflow-escalation loop
    backstops the tail, so a model miss costs a retry, never correctness).
    The legacy ``2·ceil(n/P)`` heuristic is both the floor — the
    *deterministic* answer whenever the measurement stays under it, so
    balanced traffic always lands on one capacity and one compiled
    executable — and the quantization unit above it (bounds jit-cache
    cardinality at ~P/2 steps while staying within one heuristic unit of
    the measured need).
    """
    f_hat = stats.f_max_paper if method == "paper" else stats.f_max_sampled
    base = min(partition.default_capacity(padded_n, num_buckets), padded_n)
    raw = math.ceil(f_hat * margin * padded_n)
    if raw <= base:
        return base
    cap = -(-raw // base) * base  # quantize up to a multiple of the heuristic
    cap = min(cap, padded_n + (-padded_n) % 8)
    return cap


def choose_batch_plan(
    stats: InputStats | None,
    num_buckets: int,
    padded_n: int,
    *,
    margin: float = 1.25,
    bitonic_max: int = SEGMENT_BITONIC_MAX,
    row_backend: str | None = None,
) -> SortPlan:
    """Plan ONE fused ``(B, padded_n)`` sim call for a segment batch.

    The batch twin of :func:`choose_plan`'s sim row (DESIGN.md §8): a
    homogeneous-dtype batch always takes the vmapped sim path — that is the
    point of coalescing — so the decisions left are the per-row kernel and
    one shared capacity:

    * rows up to ``bitonic_max`` take a bitonic method — a direct
      sentinel-padded row sort with **no** value partitioning.  At serving
      row sizes the P-way bucket machinery (O(L·P) rank matrix + scatter +
      P per-bucket sorts) costs an order of magnitude more device time than
      sorting the row outright, needs no capacity, and is immune to value
      skew — the fused batch IS the parallelism.  ``row_backend`` selects
      the kernel (:data:`ROW_BACKENDS`): ``vmap`` → ``bitonic`` (vmapped
      XLA sort, the default), ``pallas`` → ``bitonic_pallas`` (the fused
      batched Pallas kernel), ``pallas2op`` → ``bitonic2op`` (its NICE
      2-op stage); the engine feeds this from the
      :func:`choose_row_backend` measured head-to-head;
    * longer rows run the paper's bucket path: ``sampled`` splitters when
      the worst row is skewed but not duplicate-dominated (quantile
      splitters cannot split one repeated value), else the equal-width
      rule, with capacity from :func:`autotune_capacity` on the worst-row
      stats — one pathological row sizes the batch buffer rather than
      overflowing it.
    """
    if padded_n <= bitonic_max:
        backend = row_backend or "vmap"
        if backend not in _BACKEND_METHODS:
            raise ValueError(f"row_backend {backend!r} not in {ROW_BACKENDS}")
        return SortPlan(
            "sim", _BACKEND_METHODS[backend], None, padded_n,
            f"segmented bitonic rows (Lbucket={padded_n} ≤ {bitonic_max}), "
            f"row_backend={backend}",
        )
    if stats is None:
        raise ValueError("choose_batch_plan needs stats for the bucket path")
    method = "sampled" if (stats.skewed and stats.dup_top_frac <= 0.25) else "paper"
    cap = autotune_capacity(stats, method, num_buckets, padded_n, margin=margin)
    return SortPlan(
        "sim", method, cap, padded_n,
        f"segmented batch ({stats.label} worst row), capacity={cap}",
    )


def choose_plan(
    stats: InputStats,
    topo: OHHCTopology,
    *,
    mesh_devices: int = 1,
    mesh_axes: Sequence[str] = (),
    host_threshold: int = 1 << 20,
    margin: float = 1.25,
) -> SortPlan:
    """Stats × topology → (path, method, capacity).  Pure and unit-testable."""
    P = topo.total_procs
    if np.dtype(stats.dtype).itemsize == 8 and not x64_enabled():
        # jnp.asarray would silently downcast 64-bit keys to 32 bits on the
        # sim and dist paths — the numpy host path is the only executor
        # that sorts the caller's actual values.
        return SortPlan(
            "host", "paper", None, None,
            f"{stats.dtype} keys without jax x64: host is the only exact path",
        )
    if mesh_devices > 1:
        if len(mesh_axes) >= 2:
            return SortPlan(
                "dist", "hier", None, None,
                "multi-axis mesh: cross the slow (optical) tier exactly once",
            )
        if abs(stats.sortedness) > 0.8:
            return SortPlan(
                "dist", "valiant", None, None,
                "pre-sorted input: two-hop routing kills direct-route send skew",
            )
        if stats.skewed:
            return SortPlan(
                "dist", "sample", None, None,
                "value skew: balanced sampled splitters",
            )
        return SortPlan(
            "dist", "paper", None, None,
            "uniform input: faithful equal-width splitters, no sample gather",
        )

    method = "sampled" if (stats.skewed and stats.dup_top_frac <= 0.25) else "paper"
    if stats.dup_top_frac > 0.25:
        # A dominant duplicate value defeats *every* splitter rule equally;
        # equal-width is cheaper, capacity autotune absorbs the hot bucket.
        method = "paper"
    # Host path: ragged buckets are exact under any splitter, so balanced
    # splitters buy nothing at wall-clock — equal-width ids are cheaper to
    # compute and total local-sort work is the same.  'sampled' only pays
    # on the sim path, where it prevents static-capacity blowup.
    if stats.n >= host_threshold:
        return SortPlan(
            "host", "paper", None, None,
            f"n={stats.n} ≥ host threshold: exact ragged buckets, no pad waste",
        )
    if stats.skewed and stats.n > (1 << 16):
        return SortPlan(
            "host", "paper", None, None,
            "large skewed input: dense (P, capacity) buffer would dwarf n",
        )
    padded_n = ops.bucketed_length(stats.n)
    cap = autotune_capacity(stats, method, P, padded_n, margin=margin)
    return SortPlan(
        "sim", method, cap, padded_n,
        f"{stats.label} input on the jit path, capacity={cap}",
    )


# --------------------------------------------------------------------------
# jit-able padded simulated sort (the engine's compiled unit)
# --------------------------------------------------------------------------
# Typed sentinels shared with dist_sort (see partition.max_sentinel for
# why these must carry an explicit dtype).
_sim_fill = partition.max_sentinel
_sim_low = partition.min_sentinel


def _paper_ids(x_pad: jax.Array, valid: jax.Array, *, P: int) -> jax.Array:
    """Exact equal-width §3.1 bucket ids of the valid prefix (traced).

    Integer dtypes: float32 maths collapses keys above 2^24 onto shared
    bucket edges (the int64/uint32 adversarial case), skewing counts away
    from the measured capacity model.  Unsigned subtraction is exact for
    any signed span via two's-complement wraparound; width = span//P + 1
    keeps every id strictly below P.  The numpy twin is
    ``workloads.host_bucket_ids`` — the two must agree bit-for-bit, the
    contract the top-k planner's host histogram relies on.
    """
    dtype = x_pad.dtype
    fill = _sim_fill(dtype)
    lo = jnp.min(jnp.where(valid, x_pad, fill))
    hi = jnp.max(jnp.where(valid, x_pad, _sim_low(dtype)))
    if jnp.issubdtype(dtype, jnp.integer):
        u = jnp.uint64 if jnp.dtype(dtype).itemsize == 8 else jnp.uint32
        lo_u = lo.astype(u)
        width = (hi.astype(u) - lo_u) // P + 1
        ids = ((x_pad.astype(u) - lo_u) // width).astype(jnp.int32)
        return jnp.clip(ids, 0, P - 1)  # pad tail may wrap below lo
    ftype = jnp.float64 if dtype == jnp.float64 else jnp.float32
    lo_f = lo.astype(ftype)
    width = (hi.astype(ftype) - lo_f) / P
    width = jnp.where(width > 0, width, 1.0)
    return jnp.clip(
        jnp.floor((x_pad.astype(ftype) - lo_f) / width), 0, P - 1
    ).astype(jnp.int32)


def _sim_topk_padded(
    x_pad: jax.Array,
    n_valid: jax.Array,
    *,
    P: int,
    keep: int,
    capacity: int,
    local_sort: Callable[[jax.Array], jax.Array],
):
    """Partial range-partition sort: the top-k skip rule on the sim path.

    Every element is bucketed by the paper's equal-width rule, but only
    the first ``keep`` bucket rows are scattered and sorted — the
    equal-width rule orders buckets by value range, so every element of a
    bucket past the cut is ≥ every kept element and the global head of
    length ``sum(counts[:keep])`` is exact (DESIGN.md §12).  Buckets past
    the cut route to the drop row alongside the pad tail.

    Returns ``(head, counts, kept_total)``: ``kept_total`` is the
    *unclipped* kept-element count, so ``sum(counts) < kept_total`` means
    a kept bucket overflowed ``capacity`` (escalate) while
    ``kept_total < k`` (host-side check) means the cut was too early
    (widen ``keep``).
    """
    n_pad = x_pad.shape[0]
    dtype = x_pad.dtype
    fill = _sim_fill(dtype)
    pos = jnp.arange(n_pad)
    valid = pos < n_valid
    ids = _paper_ids(x_pad, valid, P=P)
    kept = valid & (ids < keep)
    kept_total = jnp.sum(kept.astype(jnp.int32))
    ids = jnp.where(kept, ids, keep)  # past-the-cut + pad tail → drop row
    buckets, counts = partition.scatter_to_buckets(
        jnp.where(kept, x_pad, fill), ids, keep + 1, capacity, fill_value=fill
    )
    buckets, counts = buckets[:keep], counts[:keep]
    buckets = jax.vmap(local_sort)(buckets)
    head = partition.unscatter(buckets, counts, min(n_pad, keep * capacity))
    return head, counts, kept_total


def _sim_sort_padded(
    x_pad: jax.Array,
    n_valid: jax.Array,
    *,
    P: int,
    capacity: int,
    method: str,
    sample_size: int,
    local_sort: Callable[[jax.Array], jax.Array],
):
    """Sort the valid prefix of a padded buffer on P simulated processors.

    Shapes are static (``x_pad`` is a pow2 bucket, ``capacity`` static);
    ``n_valid`` is traced, so every length in the bucket shares one
    executable.  Invalid tail elements route to an overflow row (bucket P)
    that is dropped — they never pollute counts or splitters.  Returns
    ``(out, counts)`` with the sorted valid prefix in ``out[:n_valid]``.
    """
    n_pad = x_pad.shape[0]
    dtype = x_pad.dtype
    fill = _sim_fill(dtype)
    pos = jnp.arange(n_pad)
    valid = pos < n_valid
    if method == "paper":
        ids = _paper_ids(x_pad, valid, P=P)
    elif method == "sampled":
        s = int(min(n_pad, sample_size))
        # Strided gather over the *valid* region only (dynamic indices are
        # jit/vmap-safe; float step avoids int overflow for large buckets).
        idx = jnp.clip(
            (jnp.arange(s) * (n_valid / s)).astype(jnp.int32), 0, n_valid - 1
        )
        sample = jnp.sort(x_pad[idx])
        splitters = sample[(np.arange(1, P) * s) // P]
        ids = partition.splitter_bucket_ids(x_pad, splitters)
    else:
        raise ValueError(f"unknown sim method {method!r}")
    ids = jnp.where(valid, ids, P)  # row P = drop row for the pad tail
    buckets, counts = partition.scatter_to_buckets(
        jnp.where(valid, x_pad, fill), ids, P + 1, capacity, fill_value=fill
    )
    buckets, counts = buckets[:P], counts[:P]
    buckets = jax.vmap(local_sort)(buckets)
    out = partition.unscatter(buckets, counts, n_pad)
    return out, counts


# --------------------------------------------------------------------------
# The engine
# --------------------------------------------------------------------------
class SortEngine:
    """Auto-dispatching, capacity-autotuning, compile-cache-warm sorter.

    Parameters
    ----------
    topo:            OHHC instance for the simulated/host paths (default 1-D
                     full, 36 processors).
    mesh/axis_names: when given (and the mesh has >1 device), large requests
                     dispatch to ``dist_sort`` over the mesh.
    host_threshold:  sizes ≥ this go to the exact numpy path.
    local_sort:      per-bucket sorter for the sim path (default
                     ``jnp.sort``; pass ``ops.make_local_sort()`` on TPU).
    fault_scenario:  optional ``net.faults.FaultScenario`` the engine serves
                     under (DESIGN.md §11): plans re-price the gather over
                     the degraded topology (``SortPlan.fault_slowdown``) and
                     an impossible scenario rewrites plans onto the healthy
                     host path — results stay exact either way.  Switch at
                     runtime with :meth:`set_fault_scenario`.
    """

    def __init__(
        self,
        topo: OHHCTopology | None = None,
        *,
        mesh=None,
        axis_names: Sequence[str] = ("data",),
        host_threshold: int = 1 << 20,
        sample_size: int = 2048,
        margin: float = 1.25,
        local_sort: Callable[[jax.Array], jax.Array] | None = None,
        fault_scenario=None,
    ):
        self.topo = topo if topo is not None else OHHCTopology(1, "full")
        self.mesh = mesh
        self.axis_names = tuple(axis_names)
        self.host_threshold = int(host_threshold)
        self.sample_size = int(sample_size)
        self.margin = float(margin)
        self.local_sort = local_sort if local_sort is not None else jnp.sort
        self.fault_scenario = fault_scenario
        self._fn_cache: dict[tuple, Callable] = {}
        self._comm_sim_cache: dict[tuple, float] = {}
        # per-scenario-name degraded classification (rebuilt rounds or the
        # GatherImpossible verdict) — warm like the caches it sits next to
        self._fault_info: dict[str, dict] = {}
        self.trace_count = 0  # incremented once per actual jit trace
        self.last_report: dict | None = None

    # ---------------------------------------------------------------- faults
    def set_fault_scenario(self, scenario) -> None:
        """Switch the engine onto (or off, with ``None``) a degraded
        topology.  Classification is cached per scenario *name*, the jit
        cache is untouched (the sorted output is fault-independent), and
        only plan pricing/pathing changes — so flapping scenarios never
        recompile (DESIGN.md §11)."""
        self.fault_scenario = scenario

    def _fault_state(self) -> "dict | None":
        """The active scenario classified: ``None`` when healthy, else a
        dict with ``impossible`` (bool), the scenario, and either the
        rebuilt degraded rounds + faulted router (possible) or the
        :class:`~repro.net.faults.GatherImpossible` detail + offending
        node set (impossible)."""
        sc = self.fault_scenario
        if sc is None or not getattr(sc, "is_degraded", False):
            return None
        info = self._fault_info.get(sc.name)
        if info is None:
            from repro.net.faults import GatherImpossible, degraded_gather_rounds

            try:
                rounds = degraded_gather_rounds(self.topo, sc)
            except GatherImpossible as e:
                info = {
                    "impossible": True,
                    "scenario": sc,
                    "detail": str(e),
                    "nodes": tuple(sorted(e.nodes)),
                }
            else:
                info = {
                    "impossible": False,
                    "scenario": sc,
                    "rounds": rounds,
                    "router": sc.router(self.topo),
                }
            self._fault_info[sc.name] = info
        return info

    def _apply_fault(self, plan: SortPlan, *, n: int, itemsize: int) -> SortPlan:
        """The fallback ladder (DESIGN.md §11): healthy → plan unchanged;
        degraded-but-possible → same path, gather re-priced over the
        rebuilt schedule (predicted slowdown lands in the reason and, for
        dist, in ``comm_sim_s``); impossible → the plan is rewritten onto
        the healthy host path, which needs no interconnect gather."""
        info = self._fault_state()
        if info is None:
            return plan
        name = info["scenario"].name
        if info["impossible"]:
            if plan.path == "host":
                return dataclasses.replace(
                    plan,
                    fault=name,
                    reason=f"{plan.reason}; fault={name}: degraded gather "
                    "impossible, host path unaffected",
                )
            return SortPlan(
                "host", "paper", None, None,
                f"fault={name}: degraded gather impossible "
                f"({info['detail']}); falling back to the healthy host path",
                fault=name,
            )
        healthy = self._comm_price(n, itemsize, None)
        degraded = self._comm_price(n, itemsize, info)
        ratio = degraded / healthy if healthy > 0 else 1.0
        plan = dataclasses.replace(
            plan,
            fault=name,
            fault_slowdown=ratio,
            reason=f"{plan.reason}; fault={name}: predicted "
            f"×{ratio:.2f} gather slowdown",
        )
        if plan.path == "dist":
            plan = dataclasses.replace(plan, comm_sim_s=degraded)
        return plan

    # -------------------------------------------------------------- planning
    def stats(self, x) -> InputStats:
        B = min(self.topo.total_procs, _MAX_STAT_BUCKETS)
        return estimate_stats(x, num_buckets=B, sample_size=self.sample_size)

    def plan(self, x, stats: InputStats | None = None) -> SortPlan:
        stats = stats if stats is not None else self.stats(x)
        mesh_devices = int(self.mesh.devices.size) if self.mesh is not None else 1
        plan = choose_plan(
            stats,
            self.topo,
            mesh_devices=mesh_devices,
            mesh_axes=self.axis_names if self.mesh is not None else (),
            host_threshold=self.host_threshold,
            margin=self.margin,
        )
        if plan.path == "dist":
            plan = dataclasses.replace(
                plan,
                comm_sim_s=self.comm_cost_estimate(
                    stats.n, itemsize=np.dtype(stats.dtype).itemsize
                ),
            )
        return self._apply_fault(
            plan, n=stats.n, itemsize=np.dtype(stats.dtype).itemsize
        )

    def _comm_price(self, n: int, itemsize: int, fault_info: "dict | None") -> float:
        """Barrier-mode gather time for one pow2 bucket, healthy
        (``fault_info=None``) or over a rebuilt degraded schedule — one
        cache, keyed by (bucket, itemsize, scenario name)."""
        from repro.net.links import LinkModel
        from repro.net.sim import simulate_gather, simulate_schedule

        bucket = ops.bucketed_length(max(2, n))
        name = None if fault_info is None else fault_info["scenario"].name
        key = ("netsim", bucket, itemsize, name)
        t = self._comm_sim_cache.get(key)
        if t is None:
            chunk = -(-bucket // self.topo.total_procs)
            if fault_info is None:
                t = simulate_gather(
                    self.topo,
                    link_model=LinkModel(),
                    chunk_sizes=chunk,
                    itemsize=itemsize,
                    barrier=True,
                ).total_time_s
            else:
                t = simulate_schedule(
                    fault_info["rounds"],
                    self.topo,
                    link_model=LinkModel(),
                    router=fault_info["router"],
                    chunk_sizes=chunk,
                    itemsize=itemsize,
                    barrier=True,
                ).total_time_s
            self._comm_sim_cache[key] = t
        return t

    def comm_cost_estimate(self, n: int, itemsize: int = 4) -> float:
        """Simulated one-way gather time (s) for an ``n``-element request.

        Runs the ``repro.net`` event-driven simulator (DESIGN.md §6) over
        this engine's topology with even ``n/P`` chunks — the link-level
        comm-cost estimate the dist path attaches to its dispatch
        decisions.  Cached per pow2 size bucket so the estimate is as warm
        as the jit cache it sits next to.  Under an active (and possible)
        fault scenario the price is the *degraded* schedule's (DESIGN.md
        §11); an impossible scenario prices healthy — the fallback ladder
        never runs the gather there.
        """
        info = self._fault_state()
        if info is not None and info["impossible"]:
            info = None
        return self._comm_price(n, itemsize, info)

    # -------------------------------------------------------------- jit cache
    def _get_sim_fn(self, padded_n: int, capacity: int, method: str, dtype, batched: bool):
        key = ("batch" if batched else "sim", padded_n, capacity, method, str(dtype))
        fn = self._fn_cache.get(key)
        if fn is not None:
            return fn
        if method in ("bitonic_pallas", "bitonic2op"):
            # The fused batched Pallas kernel (kernels/batched.py): ONE
            # pallas_call whose grid IS the batch axis, sentinel-fill +
            # sort + validity mask per row — no vmap wrapper, the whole
            # (B, L) batch goes in.  Counts are the trivial per-row totals
            # (same no-overflow contract as the vmapped bitonic method).
            if not batched:
                raise ValueError(f"method {method!r} is batch-only")
            interpret = ops._auto_interpret(None)
            kernel_method = "bitonic2op" if method == "bitonic2op" else "bitonic"

            def traced_batch(x_pad, n_valid):
                self.trace_count += 1  # runs at trace time only
                out = batched_kernels.batched_row_sort(
                    x_pad, n_valid, method=kernel_method, interpret=interpret
                )
                return out, n_valid.astype(jnp.int32)[:, None]

            fn = jax.jit(traced_batch)
            self._fn_cache[key] = fn
            return fn

        def traced(x_pad, n_valid):
            self.trace_count += 1  # runs at trace time only
            if method == "bitonic":
                # Direct sentinel-padded row sort (segmented batch rows,
                # DESIGN.md §8): pad cells carry the dtype max, which
                # sorts to the tail, so the valid prefix is exact even
                # when real keys equal the sentinel.  Counts are the
                # trivial per-row total — this kernel cannot overflow.
                return (
                    self.local_sort(x_pad),
                    jnp.reshape(n_valid.astype(jnp.int32), (1,)),
                )
            return _sim_sort_padded(
                x_pad,
                n_valid,
                P=self.topo.total_procs,
                capacity=capacity,
                method=method,
                sample_size=min(self.sample_size, padded_n),
                local_sort=self.local_sort,
            )

        fn = jax.jit(jax.vmap(traced) if batched else traced)
        self._fn_cache[key] = fn
        return fn

    # ------------------------------------------------------------------ sort
    def sort(self, x, *, plan: SortPlan | None = None) -> np.ndarray:
        """Globally sort ``x``; always exact (overflow escalates capacity).

        Keys must be NaN-free: like every range-partitioning sort in this
        repo, NaN poisons the min/max splitter computation (NaN also
        compares after the +inf pad fill, so such elements can vanish from
        the valid prefix).  Pre-filter NaNs before sorting float keys.
        """
        x_np = np.asarray(x).ravel()
        n = x_np.size
        if n <= 1:
            self.last_report = {"plan": None, "n": n, "overflow_retries": 0}
            return x_np.copy()
        # Stats are only measured when something consumes them: planning
        # (no explicit plan) or the dist path's capacity factor.  A forced
        # sim/host plan skips the sample entirely.
        stats = None
        if plan is None:
            stats = self.stats(x_np)
            plan = self.plan(x_np, stats)  # fault ladder applied inside
        else:
            # Forced plans go through the same ladder: an impossible
            # scenario rewrites even an explicit sim/dist plan onto the
            # healthy host path — that override IS the degraded-serving
            # contract (zero wrong answers, DESIGN.md §11).
            plan = self._apply_fault(plan, n=n, itemsize=x_np.dtype.itemsize)
        if plan.path == "host":
            r = ohhc_sort_host(x_np, self.topo, method=plan.method)
            self.last_report = {
                "plan": plan, "n": n, "stats": stats, "overflow_retries": 0,
                "counts_sum": int(r.bucket_sizes.sum()),
                "counts": np.asarray(r.bucket_sizes),
            }
            return r.sorted_array
        if plan.path == "dist":
            return self._sort_dist(x_np, plan, stats)
        return self._sort_sim(x_np, plan, stats)

    def _sort_sim(self, x_np: np.ndarray, plan: SortPlan, stats) -> np.ndarray:
        n = x_np.size
        padded_n = plan.padded_n or ops.bucketed_length(n)
        capacity = plan.capacity or partition.default_capacity(padded_n, self.topo.total_procs)
        x_pad = np.zeros(padded_n, x_np.dtype)
        x_pad[:n] = x_np
        xj = jnp.asarray(x_pad)
        retries = 0
        while True:
            fn = self._get_sim_fn(padded_n, capacity, plan.method, x_np.dtype, False)
            out, counts = fn(xj, n)
            got = int(jnp.sum(counts))
            if got == n:
                break
            # Measured-model miss: escalate capacity (×2, cap at padded_n —
            # which by construction cannot overflow) and re-run.
            if capacity >= padded_n:
                raise AssertionError("overflow with capacity == padded_n")
            capacity = min(padded_n, capacity * 2)
            capacity += (-capacity) % 8
            retries += 1
        self.last_report = {
            "plan": plan, "n": n, "stats": stats, "capacity_used": capacity,
            "counts_sum": got, "overflow_retries": retries,
            "counts": np.asarray(counts),
        }
        return np.asarray(out)[:n]

    # --------------------------------------------------------------- batched
    def plan_segments(self, keys, seg_lens) -> SortPlan:
        """Batch plan (method + shared capacity) for ``sort_segments`` traffic.

        Packs, measures worst-row stats (``estimate_batch_stats``) and runs
        the batch policy (``choose_batch_plan``) without executing the sort —
        the introspection hook the sortd service and benchmarks use.
        """
        keys = np.asarray(keys).ravel()
        lens = np.asarray(seg_lens, dtype=np.int64).ravel()
        padded_n = ops.bucketed_length(int(lens.max()) if lens.size else 1)
        stats = None
        if padded_n > SEGMENT_BITONIC_MAX:
            padded = partition.pack_segments(keys, lens, padded_n)
            stats = estimate_batch_stats(
                padded, lens,
                num_buckets=min(self.topo.total_procs, _MAX_STAT_BUCKETS),
            )
            return choose_batch_plan(
                stats, self.topo.total_procs, padded_n, margin=self.margin
            )
        backend, detail = choose_row_backend(
            padded_n, keys.dtype, local_sort=self.local_sort,
            batch_hint=int(lens.size),
        )
        plan = choose_batch_plan(
            None, self.topo.total_procs, padded_n,
            margin=self.margin, row_backend=backend,
        )
        return dataclasses.replace(plan, reason=f"{plan.reason}; {detail}")

    def sort_segments(
        self, keys, seg_lens, *, plan: SortPlan | None = None,
        return_padded: bool = False,
    ):
        """Sort ``B`` variable-length segments in ONE padded device call.

        ``keys`` is the flat concatenation of the segments and ``seg_lens``
        their lengths — the fused serving primitive (DESIGN.md §8): the whole
        batch packs into one ``(B, Lbucket)`` sentinel-padded matrix
        (``partition.pack_segments``, ``Lbucket`` the pow2 shape bucket of the
        longest segment), batch stats and capacity come from one vectorized
        worst-row measurement (no per-row Python loop), and a single vmapped
        executable from the warm jit cache sorts every row.  Both traced
        axes are shape-bucketed: rows pad to the pow2 ``Lbucket`` and the
        batch axis pads to a pow2 with zero-length phantom rows, so a
        serving stream of arbitrary (B, length) mixes reuses a handful of
        executables.  Overflow escalates capacity ×2 exactly like ``sort``,
        so results are always exact.

        Returns a list of sorted numpy segments; with ``return_padded=True``
        the raw device-resident ``(B, Lbucket)`` output instead (row ``i``'s
        sorted segment is ``out[i, :seg_lens[i]]``) — nothing but the tiny
        per-row counts check crosses back to the host, so pipelines can keep
        chaining device work without a payload sync.

        64-bit keys without jax x64 have no exact jit path (``choose_plan``'s
        host rule); they fall back to an exact per-segment host sort and
        cannot honor ``return_padded``.
        """
        keys = np.asarray(keys).ravel()
        lens = np.asarray(seg_lens, dtype=np.int64).ravel()
        if (lens < 0).any():
            raise ValueError("sort_segments: negative segment length")
        if int(lens.sum()) != keys.size:
            raise ValueError(
                f"sort_segments: seg_lens sum to {int(lens.sum())} "
                f"but keys has {keys.size} elements"
            )
        B = int(lens.size)
        total = keys.size
        max_n = int(lens.max()) if B else 0
        if keys.dtype.itemsize == 8 and not x64_enabled():
            if return_padded:
                raise ValueError(
                    "return_padded needs the jit path; 64-bit keys without "
                    "x64 only have the exact host fallback"
                )
            outs = [
                np.sort(seg)
                for seg in np.split(keys, np.cumsum(lens)[:-1])
            ] if B else []
            self.last_report = {
                "plan": SortPlan(
                    "host", "paper", None, None,
                    f"{keys.dtype} segments without jax x64: exact host fallback",
                ),
                "n": total, "batch": B, "overflow_retries": 0,
            }
            return outs
        fault_info = self._fault_state()
        if fault_info is not None and fault_info["impossible"]:
            # The batched twin of the 64-bit host fallback above: an
            # impossible scenario has no degraded gather to run, so serve
            # the batch exactly on the healthy host path (DESIGN.md §11).
            if return_padded:
                raise ValueError(
                    "return_padded needs the jit path; fault scenario "
                    f"{fault_info['scenario'].name!r} makes the degraded "
                    "gather impossible and forces the host fallback"
                )
            outs = [
                np.sort(seg)
                for seg in np.split(keys, np.cumsum(lens)[:-1])
            ] if B else []
            self.last_report = {
                "plan": SortPlan(
                    "host", "paper", None, None,
                    f"fault={fault_info['scenario'].name}: degraded gather "
                    f"impossible ({fault_info['detail']}); exact host fallback",
                    fault=fault_info["scenario"].name,
                ),
                "n": total, "batch": B, "overflow_retries": 0,
            }
            return outs
        padded_n = ops.bucketed_length(max(max_n, 1))
        if B == 0 or max_n <= 1:
            # Nothing to sort row-wise; keep the trivial case off the device.
            self.last_report = {
                "plan": SortPlan("sim", "paper", None, padded_n, "trivial batch"),
                "n": total, "batch": B, "overflow_retries": 0,
            }
            if return_padded:
                return jnp.asarray(partition.pack_segments(keys, lens, padded_n))
            return partition.unpack_segments(
                partition.pack_segments(keys, lens, padded_n), lens
            )
        # The batch axis is part of the traced shape: without bucketing it,
        # every distinct flush size B would compile its own executable (a
        # ~seconds stall per size on this container).  Pad B up to a pow2
        # with zero-length phantom rows — they carry no valid elements, so
        # stats, capacity and counts ignore them; worst-case extra row work
        # is bounded at 2× and the executable count at log2(max_batch).
        # Serving-size (bitonic) rows get a floor of 8 — phantom rows are
        # cheap there and the floor collapses the smallest batch sizes onto
        # one executable; bucket-path rows are expensive enough that a
        # phantom row floor would dominate a small batch's device time.
        b_floor = 3 if padded_n <= SEGMENT_BITONIC_MAX else 0
        B_pad = 1 << max(int(B - 1).bit_length(), b_floor)
        lens_pad = np.zeros(B_pad, np.int64)
        lens_pad[:B] = lens
        padded = partition.pack_segments(keys, lens_pad, padded_n)
        stats = None
        if plan is None:
            if padded_n <= SEGMENT_BITONIC_MAX:
                # the bitonic row kernels need no capacity → no stats pass;
                # the backend (vmap vs fused Pallas) comes from the cached
                # measured head-to-head (or REPRO_ROW_BACKEND)
                backend, detail = choose_row_backend(
                    padded_n, keys.dtype, local_sort=self.local_sort,
                    batch_hint=B_pad,
                )
                plan = choose_batch_plan(
                    None, self.topo.total_procs, padded_n,
                    margin=self.margin, row_backend=backend,
                )
                plan = dataclasses.replace(plan, reason=f"{plan.reason}; {detail}")
            else:
                stats = estimate_batch_stats(
                    padded, lens_pad,
                    num_buckets=min(self.topo.total_procs, _MAX_STAT_BUCKETS),
                )
                plan = choose_batch_plan(
                    stats, self.topo.total_procs, padded_n, margin=self.margin
                )
        # Degraded-but-possible scenario: same fused sim path, plan
        # annotated with the predicted gather slowdown (impossible was
        # already rerouted to the host fallback above).
        plan = self._apply_fault(plan, n=max(total, 1), itemsize=keys.dtype.itemsize)
        if plan.path != "sim":
            raise ValueError(f"sort_segments only runs the sim path, got {plan.path!r}")
        method = plan.method
        capacity = 0 if method in BITONIC_METHODS else (
            plan.capacity
            or partition.default_capacity(padded_n, self.topo.total_procs)
        )
        xj = jnp.asarray(padded)
        nsj = jnp.asarray(lens_pad.astype(np.int32))
        retries = 0
        while True:
            fn = self._get_sim_fn(padded_n, capacity, method, keys.dtype, True)
            out, counts = fn(xj, nsj)
            per_row = np.asarray(jnp.sum(counts, axis=-1))
            if np.array_equal(per_row, lens_pad):
                break
            if capacity >= padded_n:
                raise AssertionError("overflow with capacity == padded_n")
            capacity = min(padded_n, capacity * 2)
            capacity += (-capacity) % 8
            retries += 1
        self.last_report = {
            "plan": dataclasses.replace(
                plan, capacity=capacity if method not in BITONIC_METHODS else None
            ),
            "n": total, "stats": stats, "batch": B, "batch_padded": B_pad,
            "overflow_retries": retries,
            "pad_cells": B * padded_n - total,  # pad-waste the metrics layer reports
        }
        if return_padded:
            return out[:B]
        return partition.unpack_segments(np.asarray(out)[:B], lens)

    def sort_many(self, xs: Sequence) -> list[np.ndarray]:
        """Sort a batch of arrays with ONE vmapped executable.

        Thin wrapper over ``sort_segments``: concatenates the batch into the
        flat segmented form and fuses it into a single padded device call —
        the pre-sortd per-array stats/dispatch loop is gone (DESIGN.md §8).
        """
        arrs = [np.asarray(a).ravel() for a in xs]
        if not arrs:
            return []
        dtype = arrs[0].dtype
        if any(a.dtype != dtype for a in arrs):
            raise ValueError("sort_many requires a homogeneous dtype batch")
        lens = [a.size for a in arrs]
        if max(lens) <= 1:
            return [a.copy() for a in arrs]
        flat = np.concatenate(arrs) if len(arrs) > 1 else arrs[0]
        return self.sort_segments(flat, lens)

    def sort_pairs(self, keys, vals):
        """Key/payload sort — flat arrays on the pair kernel, pytrees via
        a permutation gather (DESIGN.md §12).

        A single flat 1-D payload array takes the legacy tagged bitonic
        pair kernel directly (warm shape cache, returns jax arrays) — the
        serving hot path (length-ordering a request batch) calls this with
        a different batch size every tick, and pow2 bucketing makes all of
        them share a handful of executables instead of one per size.

        Any other payload pytree (nested dicts/tuples, mixed dtypes,
        multi-dim leaves) rides :meth:`argsort_keys`: the same tagged pair
        kernel sorts ``(key, index)`` once, then every flattened leaf is
        gathered by the permutation on the host — byte-exact for every
        leaf dtype (64-bit leaves survive without jax x64).  Returns
        ``(sorted_keys, same-structure payload)`` as numpy.
        """
        leaves, treedef = jax.tree_util.tree_flatten(vals)
        if (
            len(leaves) == 1
            and treedef == jax.tree_util.tree_structure(0)
            and np.ndim(leaves[0]) == 1
        ):
            return self._sort_pairs_flat(keys, leaves[0])
        return self._sort_pairs_tree(keys, leaves, treedef)

    def _sort_pairs_flat(self, keys, vals):
        """The legacy flat path: one payload array through the tagged
        bitonic pair kernel (sentinel-tie safe, n_valid traced)."""
        keys = jnp.asarray(keys).ravel()
        vals = jnp.asarray(vals).ravel()
        n = keys.shape[0]
        if n <= 1:
            return keys, vals
        n_pad = ops.bucketed_length(n)
        key = ("pairs", n_pad, str(keys.dtype), str(vals.dtype))
        fn = self._fn_cache.get(key)
        if fn is None:
            def traced(k, v, n_valid):
                self.trace_count += 1
                # n_valid is traced: the pre-pad below makes every length in
                # the bucket look like n_pad to the kernel, so the validity
                # boundary must ride along or pad zeros could displace real
                # payloads on dtype-max key ties (the sentinel-tie hazard).
                return ops.local_sort_pairs(k, v, n_valid=n_valid)

            fn = jax.jit(traced)
            self._fn_cache[key] = fn
        fill = _sim_fill(keys.dtype)
        kp = jnp.concatenate([keys, jnp.full((n_pad - n,), fill, keys.dtype)])
        vp = jnp.concatenate([vals, jnp.zeros((n_pad - n,), vals.dtype)])
        ks, vs = fn(kp, vp, n)
        return ks[:n], vs[:n]

    def argsort_keys(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """``(sorted_keys, permutation)`` with ``sorted_keys == keys[perm]``.

        The permutation comes from the tagged pair kernel sorting
        ``(key, arange)`` — the sentinel-tie-safe path, so keys equal to
        the dtype max keep their payload.  64-bit keys without jax x64 and
        arrays past the kernel's ``MAX_TILE`` take the host stable argsort
        (the same exactness rule as ``choose_plan``'s host fallback).
        """
        keys_np = np.asarray(keys).ravel()
        n = keys_np.size
        if n <= 1:
            return keys_np.copy(), np.arange(n, dtype=np.int64)
        if (keys_np.dtype.itemsize == 8 and not x64_enabled()) or (
            ops.bucketed_length(n) > ops.MAX_TILE
        ):
            perm = np.argsort(keys_np, kind="stable")
            self.last_report = {
                "plan": SortPlan(
                    "host", "pairs", None, None,
                    f"argsort: {keys_np.dtype} n={n} host stable argsort "
                    "(x64/tile exactness rule)",
                ),
                "n": n, "overflow_retries": 0, "counts_sum": n,
            }
            return keys_np[perm], perm
        ks, perm = self._sort_pairs_flat(keys_np, np.arange(n, dtype=np.int32))
        self.last_report = {
            "plan": SortPlan(
                "sim", "pairs", None, ops.bucketed_length(n),
                f"argsort: tagged pair kernel over (key, arange), n={n}",
            ),
            "n": n, "overflow_retries": 0, "counts_sum": n,
        }
        return np.asarray(ks), np.asarray(perm).astype(np.int64)

    def _sort_pairs_tree(self, keys, leaves, treedef):
        """Pytree payload path: one key argsort, then a host gather of
        every flattened leaf along its leading axis (byte-exact)."""
        keys_np = np.asarray(keys).ravel()
        n = keys_np.size
        np_leaves = [np.asarray(leaf) for leaf in leaves]
        for i, leaf in enumerate(np_leaves):
            if leaf.ndim < 1 or leaf.shape[0] != n:
                raise ValueError(
                    f"sort_pairs: payload leaf {i} has shape {leaf.shape}; "
                    f"leading dim must equal n={n}"
                )
        if n <= 1:
            out_leaves = [leaf.copy() for leaf in np_leaves]
            return keys_np.copy(), jax.tree_util.tree_unflatten(
                treedef, out_leaves
            )
        ks, perm = self.argsort_keys(keys_np)
        out_leaves = [leaf[perm] for leaf in np_leaves]
        return ks, jax.tree_util.tree_unflatten(treedef, out_leaves)

    # ----------------------------------------------------------------- top-k
    def _check_top_k(self, n: int, k) -> int:
        if isinstance(k, bool) or not isinstance(k, (int, np.integer)):
            raise TypeError(f"top_k: k must be an int, got {type(k).__name__}")
        k = int(k)
        if k < 0:
            raise ValueError(f"top_k: k must be >= 0, got {k}")
        if k > n:
            raise TopKTooLarge(f"top_k: k={k} exceeds n={n}")
        return k

    def _plan_top_k_info(self, x_np: np.ndarray, k: int):
        """Plan + exact skip/capacity accounting for one top-k request.

        One O(n) host histogram under the *exact* kernel bucket rule
        (``workloads.host_bucket_ids``) yields the cut bucket, the
        skipped-bucket count, and — the satellite fix — a capacity sized
        to the KEPT buckets only: a full sort's ``autotune_capacity`` is
        worst-bucket-sized over the whole array, and a top-k plan must not
        inherit a capacity paid for buckets it skips.
        """
        n = x_np.size
        P = self.topo.total_procs
        ids = workloads.host_bucket_ids(x_np, P)
        counts = np.bincount(ids, minlength=P)
        keep, skipped = workloads.topk_cut(counts, k)
        kept_count = int(counts[:keep].sum())
        # Static-shape quantization for the jit cache: the executed kept
        # prefix is the pow2 ceiling of the exact cut (capped at P), so
        # nearby cuts share one executable.
        keep_exec = min(P, 1 << int(keep - 1).bit_length())
        padded_n = ops.bucketed_length(n)
        if (
            (x_np.dtype.itemsize == 8 and not x64_enabled())
            or n >= self.host_threshold
            or kept_count <= n // 4
        ):
            # Small heads (or no exact jit path): the host executor sorts
            # only the kept prefix — numpy on n/4 elements beats a padded
            # device round-trip of the whole array.
            plan = SortPlan(
                "host", "topk", None, None,
                f"top_k k={k}: skipped={skipped}/{P} buckets past the cut, "
                f"kept {kept_count}/{n} keys; exact host head",
            )
        else:
            cap = max(int(counts[:keep_exec].max()), 8)
            cap += (-cap) % 8
            cap = min(cap, padded_n + (-padded_n) % 8)
            plan = SortPlan(
                "sim", "topk", cap, padded_n,
                f"top_k k={k}: skipped={P - keep_exec}/{P} buckets past the "
                f"cut (exact cut {keep}, pow2 exec {keep_exec}), kept-bucket "
                f"capacity={cap}",
            )
        plan = self._apply_fault(plan, n=n, itemsize=x_np.dtype.itemsize)
        info = {
            "keep": keep,
            "keep_exec": keep_exec,
            "skipped": skipped,
            "kept_count": kept_count,
            "counts": counts,
        }
        return plan, info

    def plan_top_k(self, x, k) -> SortPlan:
        """The top-k dispatch decision without executing it — the
        introspection twin of :meth:`plan` for the head workload."""
        x_np = np.asarray(x).ravel()
        k = self._check_top_k(x_np.size, k)
        if k == 0 or x_np.size <= 1:
            return SortPlan(
                "host", "topk", None, None, f"top_k k={k}: trivial head"
            )
        return self._plan_top_k_info(x_np, k)[0]

    def top_k(self, x, k, *, plan: SortPlan | None = None) -> np.ndarray:
        """The sorted head ``np.sort(x)[:k]`` without sorting past rank k.

        Reuses the partition kernel's bucket machinery: the equal-width
        rule orders buckets by value range, so once the cumulative bucket
        histogram covers ``k`` every later bucket is wholly past the head
        and is skipped (``SortPlan.reason`` reports the skipped-bucket
        count).  Always exact, ties at rank k included — the head is a
        prefix of the true sorted order.  ``k > n`` raises
        :class:`~repro.core.workloads.TopKTooLarge`.
        """
        x_np = np.asarray(x).ravel()
        n = x_np.size
        k = self._check_top_k(n, k)
        P = self.topo.total_procs
        if k == 0 or n == 0:
            self.last_report = {
                "plan": None, "n": n, "k": k, "overflow_retries": 0,
                "skipped_buckets": P, "kept_count": 0,
            }
            return x_np[:0].copy()
        if n <= 1:
            self.last_report = {
                "plan": None, "n": n, "k": k, "overflow_retries": 0,
                "skipped_buckets": 0, "kept_count": n,
            }
            return x_np.copy()
        auto_plan, info = self._plan_top_k_info(x_np, k)
        if plan is None:
            plan = auto_plan
        else:
            plan = self._apply_fault(plan, n=n, itemsize=x_np.dtype.itemsize)
        if plan.path != "sim":
            head, hinfo = workloads.host_top_k(x_np, k, P)
            self.last_report = {
                "plan": plan, "n": n, "k": k, "overflow_retries": 0,
                "skipped_buckets": hinfo["skipped_buckets"],
                "kept_count": hinfo["kept_count"],
                "counts_sum": hinfo["kept_count"],
            }
            return head
        padded_n = plan.padded_n or ops.bucketed_length(n)
        capacity = plan.capacity or partition.default_capacity(padded_n, P)
        keep = info["keep_exec"]
        x_pad = np.zeros(padded_n, x_np.dtype)
        x_pad[:n] = x_np
        xj = jnp.asarray(x_pad)
        retries = 0
        while True:
            fn = self._get_topk_fn(padded_n, capacity, keep, x_np.dtype)
            head_pad, counts, kept_total = fn(xj, n)
            kept_total = int(kept_total)
            got = int(jnp.sum(counts))
            if got < kept_total:
                # A kept bucket overflowed its (kept-only) capacity:
                # escalate ×2 exactly like sort's retry loop.
                if capacity >= padded_n:
                    raise AssertionError("overflow with capacity == padded_n")
                capacity = min(padded_n, capacity * 2)
                capacity += (-capacity) % 8
                retries += 1
                continue
            if kept_total < k:
                # A forced/stale plan cut too early: widen the kept prefix.
                if keep >= P:
                    raise AssertionError("top_k cut miss with keep == P")
                keep = min(P, keep * 2)
                retries += 1
                continue
            break
        self.last_report = {
            "plan": plan, "n": n, "k": k, "capacity_used": capacity,
            "skipped_buckets": P - keep, "kept_count": kept_total,
            "counts_sum": got, "overflow_retries": retries,
            "counts": np.asarray(counts),
        }
        return np.asarray(head_pad)[:k]

    def _get_topk_fn(self, padded_n: int, capacity: int, keep: int, dtype):
        key = ("topk", padded_n, capacity, keep, str(dtype))
        fn = self._fn_cache.get(key)
        if fn is None:
            def traced(x_pad, n_valid):
                self.trace_count += 1  # runs at trace time only
                return _sim_topk_padded(
                    x_pad, n_valid, P=self.topo.total_procs, keep=keep,
                    capacity=capacity, local_sort=self.local_sort,
                )

            fn = jax.jit(traced)
            self._fn_cache[key] = fn
        return fn

    # ----------------------------------------------------------------- merge
    def merge_sorted(self, sorted_buf, new_keys) -> np.ndarray:
        """Fold ``new_keys`` into an already-sorted buffer incrementally.

        The streaming workload (DESIGN.md §12): a buffer that grows every
        tick no longer pays O(n log n) per tick — the increment goes
        through the full engine dispatch (``sort``) and the two ascending
        runs fuse in O(n + m) with the ``searchsorted`` gather, the
        paper's merge-free accumulation applied across time.  The buffer
        must already be ascending (validated, O(n)); dtype mismatches are
        a typed error, never a silent cast.
        """
        buf = np.asarray(sorted_buf).ravel()
        new = np.asarray(new_keys).ravel()
        if buf.dtype != new.dtype:
            raise ValueError(
                f"merge_sorted: dtype mismatch — buffer {buf.dtype} "
                f"vs new keys {new.dtype}"
            )
        if not workloads.check_sorted(buf):
            raise ValueError(
                "merge_sorted: sorted_buf is not ascending — sort it first"
            )
        if new.size == 0:
            self.last_report = {
                "plan": SortPlan(
                    "host", "merge", None, None,
                    f"merge: empty increment onto |buf|={buf.size}",
                ),
                "n": buf.size, "overflow_retries": 0,
                "counts_sum": buf.size, "merged_new": 0,
            }
            return buf.copy()
        inner_plan = None
        retries = 0
        if new.size > 1:
            new_sorted = self.sort(new)  # full dispatch for the increment
            inner = self.last_report or {}
            inner_plan = inner.get("plan")
            retries = int(inner.get("overflow_retries", 0))
        else:
            new_sorted = new
        out = workloads.merge_sorted_arrays(buf, new_sorted)
        plan = SortPlan(
            "host", "merge", None, None,
            f"merge: |buf|={buf.size} reused sorted, |new|={new.size} "
            f"engine-sorted ({getattr(inner_plan, 'path', 'trivial')}"
            f"/{getattr(inner_plan, 'method', '-')}), "
            "O(n+m) searchsorted gather",
        )
        self.last_report = {
            "plan": plan, "n": out.size, "overflow_retries": retries,
            "counts_sum": out.size, "merged_new": int(new.size),
            "inner_plan": inner_plan,
        }
        return out

    # ------------------------------------------------------------------ dist
    def _sort_dist(self, x_np: np.ndarray, plan: SortPlan, stats) -> np.ndarray:
        from repro.core.dist_sort import dist_sort

        if stats is None:
            stats = self.stats(x_np)
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        num_shards = 1
        for ax in self.axis_names:
            num_shards *= sizes[ax]
        n = x_np.size
        pad = (-n) % num_shards
        if pad:
            fill = (
                np.iinfo(x_np.dtype).max
                if np.issubdtype(x_np.dtype, np.integer)
                else np.inf
            )
            x_np = np.concatenate([x_np, np.full(pad, fill, x_np.dtype)])
        f_hat = stats.f_max_sampled if plan.method != "paper" else stats.f_max_paper
        cf = max(2.0, self.margin * f_hat * num_shards * 2.0)
        xj = jnp.asarray(x_np)
        retries = 0
        while True:
            vals, counts = dist_sort(
                xj,
                mesh=self.mesh,
                axis_names=self.axis_names,
                method=plan.method,
                capacity_factor=cf,
            )
            counts = np.asarray(counts).ravel()
            if int(counts.sum()) == x_np.size:
                break
            # Overflow drops elements (dist_sort contract); escalate like
            # the sim path.  cf == num_shards cannot overflow: every dest
            # row then holds a sender's whole shard.
            if cf >= num_shards:
                raise AssertionError("dist overflow at capacity_factor == shards")
            cf = min(float(num_shards), cf * 2.0)
            retries += 1
        vals = np.asarray(vals)
        shards = np.split(vals, counts.size)
        out = np.concatenate(
            [sh[: int(c)] for sh, c in zip(shards, counts)]
        )
        self.last_report = {
            "plan": plan, "n": n, "stats": stats,
            # counts includes the shard-divisibility pad (max-sentinel
            # elements that sort to the tail and are sliced off below);
            # report caller elements so conservation means counts_sum == n.
            "counts_sum": int(counts.sum()) - pad, "overflow_retries": retries,
            "comm_sim_s": (
                plan.comm_sim_s
                if plan.comm_sim_s is not None
                else self.comm_cost_estimate(n, itemsize=x_np.dtype.itemsize)
            ),
        }
        return out[:n]
