"""The paper's 3-phase hierarchical accumulation schedule (§3.2, Figs 3.1–3.5).

The algorithm gathers every processor's sorted bucket to the *master* node
(group 0, local 0) through a static spanning tree that mirrors the link
hierarchy:

  Phase A  (Fig 3.1)  intra-HHC accumulation, all groups in parallel:
           round 1:  5→0, 3→1, 4→2   (cross + triangle edges)
           round 2:  1→0, 2→0        (triangle edges)
  Phase B  (Fig 3.2)  binomial-tree hypercube accumulation among the HHC
           cell heads of each group: cell with lowest set bit b sends its
           accumulated 6·2**(b) ... payload to (cell − 2**b), rounds
           b = 0 .. d_h−2.
  Phase C  (Fig 3.3)  the single optical hop: head of group g (node (g,0))
           sends the whole group payload over its OTIS link to node
           (0, g).  NOTE: the paper's prose states the OTIS transpose rule
           "node x in group y is connected to node y in group x"; the
           pseudo-code's ``SendTo`` arithmetic evaluates to an index inside
           the *sending* group, which contradicts the prose.  We implement
           the prose (see DESIGN.md §2).
  Phase D  (Figs 3.4/3.5)  group-0 accumulation with adjusted wait counts:
           same edge pattern as A+B, but nodes now carry a full group
           payload each.  The paper hard-codes the wait constants for
           G=P (normal=P+1, aggregate=2(P+1), head=6(P+1),
           master=5(P+1)+1); we *derive* every node's wait count from the
           schedule tree, which reproduces those constants and also covers
           G=P/2, where nodes ``local ≥ G`` receive no optical payload.

Every node's "wait for" amount is static — the paper's key scheduling
idea — so the whole gather is a compiled, coordination-free program.
This module builds the schedule as explicit rounds of (src, dst) sends,
computes per-node wait counts, per-round payloads, the spanning-tree send
count, the critical-path round count, and the paper's Theorem-3 step
accounting (including its d_h ≥ 3 arithmetic slip — see
``paper_step_count`` / ``tree_send_count``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.topology import HHC_SIZE, OHHCTopology


@dataclasses.dataclass(frozen=True)
class Send:
    """One point-to-point message: src/dst are (group, local) addresses."""

    src: tuple[int, int]
    dst: tuple[int, int]
    link: str  # 'electrical' | 'optical'
    phase: str  # 'A' | 'B' | 'C' | 'D-hhc' | 'D-cube'


@dataclasses.dataclass(frozen=True)
class AccumulationSchedule:
    """The full gather-to-master schedule as a list of parallel rounds."""

    topo: OHHCTopology
    rounds: tuple[tuple[Send, ...], ...]

    # ------------------------------------------------------------------ build
    @classmethod
    def build(cls, topo: OHHCTopology) -> "AccumulationSchedule":
        rounds: list[list[Send]] = []
        cells = topo.num_hhc_cells
        G = topo.num_groups

        def hhc_rounds(groups: list[int], phase: str) -> list[list[Send]]:
            """Fig 3.1 pattern inside each listed group: 2 rounds."""
            r1, r2 = [], []
            for g in groups:
                for c in range(cells):
                    base = c * HHC_SIZE
                    r1 += [
                        Send((g, base + 5), (g, base + 0), "electrical", phase),
                        Send((g, base + 3), (g, base + 1), "electrical", phase),
                        Send((g, base + 4), (g, base + 2), "electrical", phase),
                    ]
                    r2 += [
                        Send((g, base + 1), (g, base + 0), "electrical", phase),
                        Send((g, base + 2), (g, base + 0), "electrical", phase),
                    ]
            return [r1, r2]

        def cube_rounds(groups: list[int], phase: str) -> list[list[Send]]:
            """Fig 3.2 binomial tree among cell heads: d_h−1 rounds."""
            out = []
            for bit in range(topo.d_h - 1):
                rnd = []
                step = 1 << bit
                for g in groups:
                    for c in range(cells):
                        # cell sends in round `bit` iff its lowest set bit is `bit`
                        if c & ((step << 1) - 1) == step:
                            rnd.append(
                                Send(
                                    (g, c * HHC_SIZE),
                                    (g, (c - step) * HHC_SIZE),
                                    "electrical",
                                    phase,
                                )
                            )
                if rnd:
                    out.append(rnd)
            return out

        # Phase A+B: every non-zero group accumulates to its head, in
        # parallel with group 0 pre-accumulating its own chunks the same way
        # (the paper runs group 0's gather in phase D with different waits;
        # the edge pattern and round structure are identical, so we schedule
        # group 0's *own-chunk* gather in D to match the paper's flow).
        non_zero = list(range(1, G))
        rounds += hhc_rounds(non_zero, "A")
        rounds += cube_rounds(non_zero, "B")

        # Phase C: one optical hop per non-zero group.
        rounds.append(
            [Send((g, 0), (0, g), "optical", "C") for g in range(1, G)]
        )

        # Phase D: group 0 gathers (own chunks + received group payloads).
        rounds += hhc_rounds([0], "D-hhc")
        rounds += cube_rounds([0], "D-cube")

        return cls(topo=topo, rounds=tuple(tuple(r) for r in rounds))

    # ------------------------------------------------------------- properties
    def all_sends(self) -> list[Send]:
        return [s for rnd in self.rounds for s in rnd]

    def tree_send_count(self) -> int:
        """Point-to-point messages in one accumulation (= spanning tree edges).

        Exactly ``total_procs − 1``: every processor except the master
        forwards its (accumulated) payload exactly once.
        """
        return len(self.all_sends())

    def critical_path_rounds(self) -> int:
        """Parallel rounds for one accumulation: 2 + (d_h−1) + 1 + 2 + (d_h−1)."""
        return len(self.rounds)

    def roundtrip_send_count(self) -> int:
        """Distribute (reverse tree) + gather."""
        return 2 * self.tree_send_count()

    def paper_step_count(self) -> int:
        """Theorem 3's accounting: 12·G·d_h − 2.

        The paper counts, per direction, ``6·d_h − 1`` electrical steps per
        group plus ``G − 1`` optical steps → ``6·G·d_h − 1`` one-way.  This
        matches the spanning-tree send count for d_h ∈ {1, 2} (where
        6·d_h = P) but *undercounts* for d_h ≥ 3, where each added
        dimension doubles the number of HHC cells (P = 6·2**(d_h−1) ≠ 6·d_h)
        — the theorem charges only 6 extra steps per dimension.  We expose
        both counts; tests pin the d_h∈{1,2} agreement and the d_h≥3 gap.
        """
        return 12 * self.topo.num_groups * self.topo.d_h - 2

    def paper_step_count_components(self) -> dict:
        G, d_h = self.topo.num_groups, self.topo.d_h
        return {
            "electrical_per_group_one_way": 6 * d_h - 1,
            "electrical_one_way": G * (6 * d_h - 1),
            "optical_one_way": G - 1,
            "one_way_total": 6 * G * d_h - 1,
            "roundtrip_total": 12 * G * d_h - 2,
        }

    # ------------------------------------------------ chunk-count simulation
    def simulate_chunk_counts(self) -> dict:
        """Walk the schedule carrying chunk counts; derive static wait counts.

        Returns per-node wait counts (chunks held when the node forwards,
        *including its own*, matching the paper's WaitForSubArrays
        semantics), the master's final count (must equal total_procs), and
        per-round payload sizes in chunks.
        """
        topo = self.topo
        held = {
            (g, l): 1
            for g in range(topo.num_groups)
            for l in range(topo.procs_per_group)
        }
        wait_counts: dict[tuple[int, int], int] = {}
        round_payload_chunks: list[dict] = []
        for rnd in self.rounds:
            payload = {"electrical": 0, "optical": 0, "sends": len(rnd)}
            # All sends in a round are parallel: read counts first.
            staged = []
            for s in rnd:
                amount = held[s.src]
                wait_counts[s.src] = amount
                staged.append((s, amount))
                payload[s.link] += amount
            for s, amount in staged:
                held[s.src] = 0
                held[s.dst] += amount
            round_payload_chunks.append(payload)
        master = held[(0, 0)]
        return {
            "wait_counts": wait_counts,
            "master_final_chunks": master,
            "round_payload_chunks": round_payload_chunks,
            "held_after": held,
        }

    def paper_wait_constants(self) -> dict:
        """The legible Fig 3.4 constants for G=P, derived from the tree.

        normal    = P+1        (nodes 3,4,5 of group 0: own chunk + one
                                optical group payload of P chunks)
        aggregate = 2(P+1)     (nodes 1,2: own P+1 plus one neighbour's)
        head      = 6(P+1)     (cell heads of non-zero cells in group 0)
        master    = 5(P+1)+1   (node (0,0): five neighbours' P+1 + own 1)
        """
        P = self.topo.procs_per_group
        return {
            "normal": P + 1,
            "aggregate": 2 * (P + 1),
            "head": 6 * (P + 1),
            "master": 5 * (P + 1) + 1,
        }


def payload_bytes_per_round(
    schedule: AccumulationSchedule,
    chunk_sizes: "list[int] | Callable[[int], int]",
    itemsize: int = 4,
) -> list[dict]:
    """Per-round payload bytes on each link class, for the cost model.

    ``chunk_sizes`` maps global processor id → its bucket length (elements).
    Returns, per round, total + max per-link-class bytes (the round's
    latency is set by its largest single message under store-and-forward).
    """
    topo = schedule.topo
    if callable(chunk_sizes):
        sizes = [chunk_sizes(i) for i in range(topo.total_procs)]
    else:
        sizes = list(chunk_sizes)
    held = {
        (g, l): sizes[topo.global_id(g, l)]
        for g in range(topo.num_groups)
        for l in range(topo.procs_per_group)
    }
    out = []
    for rnd in schedule.rounds:
        stats = {
            "electrical_bytes": 0,
            "optical_bytes": 0,
            "max_msg_bytes": 0,
            "link": rnd[0].link if rnd else "electrical",
        }
        staged = []
        for s in rnd:
            amt = held[s.src] * itemsize
            stats[f"{s.link}_bytes"] += amt
            stats["max_msg_bytes"] = max(stats["max_msg_bytes"], amt)
            staged.append((s, held[s.src]))
        for s, amt in staged:
            held[s.src] = 0
            held[s.dst] += amt
        out.append(stats)
    return out
