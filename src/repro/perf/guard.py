"""Perf-regression gate: fresh run vs committed baseline (DESIGN.md §9).

The perf twin of ``repro.verify.baseline``'s drift gate.  Each measured
:class:`~repro.perf.schema.PerfRecord` is judged against the committed
reference for its ``case_id`` on the *normalized* ratio (see
``repro.perf.normalize``), under the baseline's own asymmetric tolerance:

* ``fail``  — regression beyond ``ref · (1 + upper)``;
* ``warn``  — inside tolerance but past the warn fraction of the band, or
  an improvement beyond ``ref · (1 - lower)`` (numbers that good usually
  mean the measurement broke or the baseline is stale — re-record);
* ``pass``  — inside the band;
* ``new``   — measured but absent from the baseline: a gate has nothing to
  gate against, so it fails until recorded;
* ``missing`` — in the baseline but not measured (a silently dropped case
  is a gate silently shrinking): fails, except on explicit subset runs
  (``--filter``/``--suite``), mirroring verify's subset diff.

A changed work model (same case id, different bytes/flops) makes the old
ratio incomparable; the case is judged ``new`` with a re-record hint, not
compared against a stale reference.  ``slack`` scales both tolerance arms
(CI shared runners run with ``--slack 2``); it never rescues ``new`` /
``missing``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.perf.schema import PerfRecord

# Inside the tolerance band but beyond this fraction of it → warn.
WARN_FRACTION = 0.75


@dataclasses.dataclass(frozen=True)
class CaseVerdict:
    """One case's judgment: status, the numbers behind it, and prose."""

    case_id: str
    status: str  # pass | warn | fail | new | missing
    value: "float | None"  # fresh norm_ratio (None for missing)
    reference: "float | None"  # baseline norm_ratio (None for new)
    rel: "float | None"  # value / reference
    detail: str

    @property
    def gate_ok(self) -> bool:
        return self.status in ("pass", "warn")


def classify(
    value: float,
    reference: float,
    *,
    lower: float,
    upper: float,
    slack: float = 1.0,
) -> "tuple[str, float, str]":
    """(status, rel, detail) for a comparable (value, reference) pair."""
    if reference <= 0:
        raise ValueError(f"non-positive reference {reference}")
    lo, up = lower * slack, upper * slack
    rel = value / reference
    if rel > 1.0 + up:
        return "fail", rel, (
            f"regression: {rel:.2f}x the reference "
            f"(tolerance +{up * 100:.0f}%)"
        )
    if rel > 1.0 + WARN_FRACTION * up:
        return "warn", rel, (
            f"approaching tolerance: {rel:.2f}x the reference "
            f"(warn past +{WARN_FRACTION * up * 100:.0f}%, fail past +{up * 100:.0f}%)"
        )
    if rel < 1.0 - lo:
        return "warn", rel, (
            f"improvement beyond tolerance: {rel:.2f}x the reference "
            f"(-{lo * 100:.0f}% band) — verify and re-record the baseline"
        )
    return "pass", rel, f"{rel:.2f}x the reference"


def _workload_matches(rec: PerfRecord, ref_entry: dict) -> bool:
    ref_w = ref_entry.get("workload")
    rec_w = None if rec.workload is None else rec.workload.as_dict()
    return ref_w == rec_w and bool(ref_entry.get("normalized")) == rec.normalized


def _roofline_delta(rec: PerfRecord, ref_entry: dict) -> str:
    ref_pct = ref_entry.get("pct_of_roofline")
    if not rec.normalized or ref_pct is None or rec.pct_of_roofline is None:
        return ""
    return (
        f"; %-of-roofline {ref_pct:.2f}% -> {rec.pct_of_roofline:.2f}% "
        f"(delta {rec.pct_of_roofline - ref_pct:+.2f}pp)"
    )


def judge(
    records: "Sequence[PerfRecord]",
    baseline: "dict | None",
    *,
    subset: bool = False,
    slack: float = 1.0,
) -> "list[CaseVerdict]":
    """Judge a suite's fresh records against its committed baseline.

    ``baseline=None`` (no committed file) makes every record ``new`` —
    the gate fails loudly instead of silently passing, exactly like a
    missing verify baseline.
    """
    cases = {} if baseline is None else baseline.get("cases", {})
    verdicts = []
    seen = set()
    for rec in records:
        seen.add(rec.case_id)
        ref = cases.get(rec.case_id)
        if ref is None:
            verdicts.append(CaseVerdict(
                case_id=rec.case_id, status="new", value=rec.norm_ratio,
                reference=None, rel=None,
                detail="not in baseline — record with --update-baseline",
            ))
            continue
        if not _workload_matches(rec, ref):
            verdicts.append(CaseVerdict(
                case_id=rec.case_id, status="new", value=rec.norm_ratio,
                reference=ref.get("norm_ratio"), rel=None,
                detail="work model changed — the recorded ratio is "
                "incomparable; re-record with --update-baseline",
            ))
            continue
        tol = ref.get("tolerance", {})
        status, rel, detail = classify(
            rec.norm_ratio, ref["norm_ratio"],
            lower=float(tol.get("lower", rec.lower)),
            upper=float(tol.get("upper", rec.upper)),
            slack=slack,
        )
        if status != "pass":
            detail += _roofline_delta(rec, ref)
        verdicts.append(CaseVerdict(
            case_id=rec.case_id, status=status, value=rec.norm_ratio,
            reference=ref["norm_ratio"], rel=rel, detail=detail,
        ))
    if not subset:
        for cid in sorted(set(cases) - seen):
            verdicts.append(CaseVerdict(
                case_id=cid, status="missing", value=None,
                reference=cases[cid].get("norm_ratio"), rel=None,
                detail="in baseline but not measured — dropped case?",
            ))
    return verdicts


def gate_ok(verdicts: "Sequence[CaseVerdict]") -> bool:
    return all(v.gate_ok for v in verdicts)


def summarize(verdicts: "Sequence[CaseVerdict]") -> dict:
    counts = {"pass": 0, "warn": 0, "fail": 0, "new": 0, "missing": 0}
    for v in verdicts:
        counts[v.status] += 1
    return counts


def markdown_report(
    suite_verdicts: "dict[str, list[CaseVerdict]]",
    *,
    hw_name: str,
    slack: float = 1.0,
) -> str:
    """Human-readable gate report (the CI artifact next to the JSON)."""
    lines = [
        "# perfguard report",
        "",
        f"normalization hw: `{hw_name}`; tolerance slack: {slack:g}x",
        "",
        "| case | status | norm ratio | reference | rel | detail |",
        "|---|---|---|---|---|---|",
    ]
    for suite in sorted(suite_verdicts):
        for v in suite_verdicts[suite]:
            fmt = lambda x: "—" if x is None else f"{x:.3f}"  # noqa: E731
            lines.append(
                f"| `{v.case_id}` | {v.status.upper()} | {fmt(v.value)} | "
                f"{fmt(v.reference)} | {fmt(v.rel)} | {v.detail} |"
            )
    totals = summarize([v for vs in suite_verdicts.values() for v in vs])
    ok = all(gate_ok(vs) for vs in suite_verdicts.values())
    lines += [
        "",
        f"**{'PASS' if ok else 'FAIL'}** — " + ", ".join(
            f"{k}: {n}" for k, n in totals.items() if n
        ),
        "",
    ]
    return "\n".join(lines)


def json_report(
    suite_verdicts: "dict[str, list[CaseVerdict]]",
    suite_records: "dict[str, list[PerfRecord]]",
    *,
    hw_name: str,
    slack: float = 1.0,
    elapsed_s: "float | None" = None,
) -> dict:
    return {
        "hw": hw_name,
        "slack": slack,
        "elapsed_s": elapsed_s,
        "gate_ok": all(gate_ok(vs) for vs in suite_verdicts.values()),
        "totals": summarize([v for vs in suite_verdicts.values() for v in vs]),
        "suites": {
            suite: {
                "verdicts": [dataclasses.asdict(v) for v in suite_verdicts[suite]],
                "records": [r.as_dict() for r in suite_records.get(suite, [])],
            }
            for suite in sorted(suite_verdicts)
        },
    }
