"""Execute perf suites under the measurement contract (DESIGN.md §9).

``run_cases`` is the only place a :class:`~repro.perf.schema.PerfCase`
becomes a :class:`~repro.perf.schema.PerfRecord`: setup (inputs + warm
executables) happens outside the timed region, each timed call is drained
via the measure layer's sync, the value is median-of-``repeats`` with IQR,
and the result is normalized against the calibrated host roofline before
anything is persisted or judged.
"""

from __future__ import annotations

from typing import Callable, Sequence

from repro.perf.measure import measure as _measure
from repro.perf.normalize import Workload, host_hw, normalize
from repro.perf.schema import PerfCase, PerfRecord
from repro.perf.suites import cases_for
from repro.roofline.hw import HW

DEFAULT_WARMUP = 2
DEFAULT_REPEATS = 5


def run_case(
    case: PerfCase,
    *,
    hw: "HW | None" = None,
    warmup: int = DEFAULT_WARMUP,
    repeats: int = DEFAULT_REPEATS,
) -> PerfRecord:
    hw = hw or host_hw()
    fn = case.setup()
    m = _measure(fn, warmup=warmup, repeats=repeats)
    return record_from_measurement(
        case_id=case.case_id,
        median_s=m.median_s,
        iqr_s=m.iqr_s,
        warmup=m.warmup,
        repeats=m.repeats,
        workload=case.workload,
        hw=hw,
        metric=case.metric,
        units=case.units,
        lower=case.lower,
        upper=case.upper,
    )


def record_from_measurement(
    *,
    case_id: str,
    median_s: float,
    iqr_s: float,
    warmup: int,
    repeats: int,
    workload: "Workload | None",
    hw: HW,
    metric: str = "time",
    units: str = "s",
    lower: float = 0.5,
    upper: float = 0.75,
) -> PerfRecord:
    """Measurement numbers → normalized record (also the test seam:
    fixtures fabricate records without timing anything)."""
    norm = normalize(median_s, workload, hw)
    return PerfRecord(
        case_id=case_id,
        metric=metric,
        units=units,
        median_s=median_s,
        iqr_s=iqr_s,
        repeats=repeats,
        warmup=warmup,
        normalized=norm["normalized"],
        roofline_s=norm["roofline_s"],
        norm_ratio=norm["norm_ratio"],
        pct_of_roofline=norm["pct_of_roofline"],
        workload=workload,
        hw_name=hw.name,
        lower=lower,
        upper=upper,
    )


def run_cases(
    cases: "Sequence[PerfCase]",
    *,
    hw: "HW | None" = None,
    warmup: int = DEFAULT_WARMUP,
    repeats: int = DEFAULT_REPEATS,
    progress: "Callable[[PerfRecord], None] | None" = None,
) -> "list[PerfRecord]":
    hw = hw or host_hw()
    records = []
    for case in cases:
        rec = run_case(case, hw=hw, warmup=warmup, repeats=repeats)
        records.append(rec)
        if progress is not None:
            progress(rec)
    return records


def run_suite(
    suite: str,
    *,
    smoke: bool = True,
    hw: "HW | None" = None,
    warmup: int = DEFAULT_WARMUP,
    repeats: int = DEFAULT_REPEATS,
    case_filter: "str | None" = None,
    progress: "Callable[[PerfRecord], None] | None" = None,
) -> "list[PerfRecord]":
    cases = cases_for(suite, smoke=smoke)
    if case_filter:
        cases = [c for c in cases if case_filter in c.case_id]
    return run_cases(cases, hw=hw, warmup=warmup, repeats=repeats, progress=progress)
