"""Machine normalization: judge perf on roofline multiples, not seconds
(DESIGN.md §9).

A raw wall-clock baseline is a property of one machine; re-run it on a
faster host and every case "improves", on a slower one everything
"regresses".  Each :class:`~repro.perf.schema.PerfCase` therefore carries
a :class:`Workload` — the bytes it must move and the useful FLOPs it must
execute per call — and the judged metric is

    norm_ratio = measured_s / roofline_s(workload, calibrated host peaks)

i.e. "how many multiples of this machine's roofline lower bound did the
call take".  Rescale every peak by k (a different machine) and both the
fresh value and a reference recorded under the same normalization scale by
the same k — the regression judgment is invariant, which is what makes a
committed ``BENCH_*.json`` portable.  ``pct_of_roofline`` (the inverse, as
a percentage) rides along for human consumption, the berkeley-ERT way.

A case without a workload model (e.g. the netsim event loop, whose cost is
events, not bytes) falls back to raw seconds; its baseline is honest but
machine-local, and the guard marks it so.
"""

from __future__ import annotations

import dataclasses

from repro.roofline.analysis import bound_time_s
from repro.roofline.hw import HW, calibrate_host


@dataclasses.dataclass(frozen=True)
class Workload:
    """Per-call work model: bytes moved and useful FLOPs executed.

    ``bytes_moved`` is the honest *lower bound* (inputs read once +
    outputs written once); a multi-pass algorithm runs at a small
    percentage of this roofline, which is fine — the guard judges ratios
    against a reference, not absolute efficiency.
    """

    bytes_moved: float
    flops: float = 0.0

    def as_dict(self) -> dict:
        return {"bytes_moved": self.bytes_moved, "flops": self.flops}


def roofline_s(workload: Workload, hw: HW) -> float:
    """Roofline lower bound for one call of this workload on ``hw``."""
    t = bound_time_s(flops=workload.flops, bytes_moved=workload.bytes_moved, hw=hw)
    if t <= 0.0:
        raise ValueError(f"workload {workload} has no positive roofline time")
    return t


def normalize(measured_s: float, workload: "Workload | None", hw: HW) -> dict:
    """The normalization record stored with every measurement.

    With a workload: ``norm_ratio`` (measured / roofline, ≥ ~1 ideally)
    and ``pct_of_roofline`` (its inverse × 100).  Without one: raw-seconds
    fallback — ``norm_ratio`` is the measured time itself and
    ``pct_of_roofline`` is None, flagged via ``normalized=False``.
    """
    if workload is None:
        return {
            "normalized": False,
            "roofline_s": None,
            "norm_ratio": measured_s,
            "pct_of_roofline": None,
        }
    ideal = roofline_s(workload, hw)
    return {
        "normalized": True,
        "roofline_s": ideal,
        "norm_ratio": measured_s / ideal,
        "pct_of_roofline": 100.0 * ideal / measured_s if measured_s > 0 else None,
    }


def host_hw() -> HW:
    """The calibrated peaks for this machine (cached per process)."""
    return calibrate_host()
