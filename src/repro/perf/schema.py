"""Perf-case schema and the committed ``BENCH_<suite>.json`` documents
(DESIGN.md §9).

Mirrors ``repro.verify.baseline``: a baseline is a JSON document mapping
``case_id`` → the reference outcome of that case, committed under
``benchmarks/baselines/``, and every change lands as a reviewable file
diff via ``tools/perfguard.py --update-baseline`` — never as a silent
drift.  Unlike verify's baselines, the recorded value here is a *number*
(the machine-normalized ratio, see ``repro.perf.normalize``) with an
asymmetric tolerance band around it, ReFrame-reference style:
``(reference, -lower, +upper)`` → fail above ``ref·(1+upper)``, warn below
``ref·(1-lower)``.

Also home of the benchmark CSV row contract (``name,us_per_call,derived``)
that ``tests/test_bench_smoke.py`` validates for every suite.
"""

from __future__ import annotations

import dataclasses
import json
import math
import pathlib
from typing import Callable, Sequence

from repro.perf.normalize import Workload

SCHEMA_VERSION = 1

# Default asymmetric tolerance band on the normalized ratio: fail beyond
# +75% regression, warn beyond -50% "improvement" (a number that good
# usually means the measurement broke or the baseline is stale).
DEFAULT_LOWER = 0.50
DEFAULT_UPPER = 0.75

# How many --update-baseline recordings the trajectory keeps.
TRAJECTORY_KEEP = 20


@dataclasses.dataclass(frozen=True)
class PerfCase:
    """One gated perf scenario: what to run, its work model, its band.

    ``setup`` returns a zero-arg callable measured under the
    ``repro.perf.measure`` contract (warmup → sync → median-of-k); inputs
    and compilation happen inside ``setup``, never inside the timed call.
    ``workload=None`` opts the case out of roofline normalization (raw
    seconds, machine-local — see ``repro.perf.normalize``).
    """

    suite: str
    key: str
    setup: "Callable[[], Callable[[], object]]"
    workload: "Workload | None"
    metric: str = "time"
    units: str = "s"
    lower: float = DEFAULT_LOWER
    upper: float = DEFAULT_UPPER
    smoke: bool = True  # in the pinned CI slice, or full-run only

    @property
    def case_id(self) -> str:
        return f"{self.suite}/{self.key}"


@dataclasses.dataclass(frozen=True)
class PerfRecord:
    """One measured outcome of a :class:`PerfCase` on this machine."""

    case_id: str
    metric: str
    units: str
    median_s: float
    iqr_s: float
    repeats: int
    warmup: int
    normalized: bool
    roofline_s: "float | None"
    norm_ratio: float
    pct_of_roofline: "float | None"
    workload: "Workload | None"
    hw_name: str
    lower: float = DEFAULT_LOWER
    upper: float = DEFAULT_UPPER

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["workload"] = None if self.workload is None else self.workload.as_dict()
        return d


def reference_entry(rec: PerfRecord) -> dict:
    """The baseline-persisted projection of one record.

    ``norm_ratio`` is the judged reference; ``raw_s``/``iqr_s``/
    ``pct_of_roofline`` are context for humans reading the diff; the
    workload is persisted so a silently changed work model (same case id,
    different bytes) is detected instead of judged against a stale ratio.
    """
    return {
        "metric": rec.metric,
        "units": rec.units,
        "normalized": rec.normalized,
        "norm_ratio": rec.norm_ratio,
        "raw_s": rec.median_s,
        "iqr_s": rec.iqr_s,
        "pct_of_roofline": rec.pct_of_roofline,
        "workload": None if rec.workload is None else rec.workload.as_dict(),
        "tolerance": {"lower": rec.lower, "upper": rec.upper},
    }


def build_baseline(
    records: "Sequence[PerfRecord]",
    *,
    suite: str,
    hw_name: str,
    recorded_utc: "str | None" = None,
    trajectory: "list | None" = None,
) -> dict:
    """Records → committed ``BENCH_<suite>.json`` document.

    ``trajectory`` is the prior document's history (each entry one
    ``--update-baseline`` recording); the new recording is appended and
    the list trimmed to :data:`TRAJECTORY_KEEP`.
    """
    cases = {r.case_id: reference_entry(r) for r in records}
    entry = {
        "recorded_utc": recorded_utc,
        "hw": hw_name,
        "norm_ratios": {cid: cases[cid]["norm_ratio"] for cid in sorted(cases)},
    }
    history = list(trajectory or []) + [entry]
    return {
        "schema": SCHEMA_VERSION,
        "suite": suite,
        "hw": hw_name,
        "case_count": len(cases),
        "cases": {k: cases[k] for k in sorted(cases)},
        "trajectory": history[-TRAJECTORY_KEEP:],
    }


def baseline_path(suite: str, directory) -> pathlib.Path:
    return pathlib.Path(directory) / f"BENCH_{suite}.json"


def save_baseline(doc: dict, path) -> None:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")


def load_baseline(path) -> dict:
    doc = json.loads(pathlib.Path(path).read_text())
    if doc.get("schema") != SCHEMA_VERSION:
        raise ValueError(
            f"perf baseline schema {doc.get('schema')!r} != supported {SCHEMA_VERSION}"
        )
    return doc


# --- benchmark CSV row contract -------------------------------------------
#
# Every benchmarks/ module prints `name,us_per_call,derived` rows
# (`benchmarks.common.emit`); `# `-prefixed lines are section markers /
# comments.  The smoke test validates every emitted row against this.


def parse_csv_row(line: str) -> "tuple[str, float, str]":
    """Parse and validate one benchmark CSV row; raises ValueError."""
    parts = line.split(",", 2)
    if len(parts) != 3:
        raise ValueError(f"row needs 3 comma fields: {line!r}")
    name, us, derived = parts
    if not name or " " in name:
        raise ValueError(f"bad row name {name!r}: {line!r}")
    try:
        v = float(us)
    except ValueError:
        raise ValueError(f"us_per_call not a number: {line!r}") from None
    if not math.isfinite(v) or v < 0:
        raise ValueError(f"us_per_call must be finite and >= 0: {line!r}")
    return name, v, derived


def validate_csv(text: str) -> "list[str]":
    """All problems in a benchmark CSV stream (header/comment lines skipped)."""
    problems = []
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        if line.strip() == "name,us_per_call,derived":
            continue
        try:
            parse_csv_row(line)
        except ValueError as e:
            problems.append(f"line {lineno}: {e}")
    return problems
