"""Timing discipline for every benchmark and perf gate (DESIGN.md §9).

One measurement contract, enforced everywhere a wall-clock number can end
up in a committed baseline:

* **warmup first** — jit compilation, allocator growth, and cache fill are
  paid before the timed region, never inside it;
* **sync before stopping the clock** — an async dispatch (jax) must be
  drained with ``block_until_ready`` or the number measures enqueue cost,
  not execution;
* **median-of-k with dispersion** — the reported value is the median of
  ``repeats`` timed calls and the IQR rides along, so a baseline diff can
  tell a real regression from a noisy sample.

``measure`` times one callable; ``measure_interleaved`` times a *group* of
configs round-robin (config A, B, C, A, B, C, …) so slow drift — allocator
warm-up, frequency scaling, a background process — biases every config
equally instead of whichever was timed first.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Mapping, Sequence

import numpy as np

DEFAULT_WARMUP = 1
DEFAULT_REPEATS = 5


def median_iqr(samples: Sequence[float]) -> tuple[float, float]:
    """(median, interquartile range) of a sample set.

    The IQR is the dispersion record every baseline carries: non-negative,
    robust to a single outlier sample, zero for a single repeat.
    """
    a = np.asarray(list(samples), dtype=np.float64)
    if a.size == 0:
        raise ValueError("median_iqr needs at least one sample")
    q25, q75 = np.percentile(a, (25.0, 75.0))
    return float(np.median(a)), float(max(q75 - q25, 0.0))


def default_sync(result) -> None:
    """Drain async work hanging off ``result`` (jax arrays / pytrees).

    numpy results (and None) are already synchronous; anything exposing
    ``block_until_ready`` is drained, and lists/tuples/dicts are walked so
    multi-output calls sync every leaf.
    """
    if result is None or isinstance(result, (np.ndarray, np.generic, int, float)):
        return
    if hasattr(result, "block_until_ready"):
        result.block_until_ready()
        return
    if isinstance(result, (list, tuple)):
        for r in result:
            default_sync(r)
    elif isinstance(result, Mapping):
        for r in result.values():
            default_sync(r)


@dataclasses.dataclass(frozen=True)
class Measurement:
    """Median-of-k timing with its dispersion and provenance."""

    median_s: float
    iqr_s: float
    min_s: float
    max_s: float
    samples_s: tuple
    warmup: int
    repeats: int

    def as_dict(self) -> dict:
        return {
            "median_s": self.median_s,
            "iqr_s": self.iqr_s,
            "min_s": self.min_s,
            "max_s": self.max_s,
            "warmup": self.warmup,
            "repeats": self.repeats,
        }


def _timed_call(fn: Callable[[], object], sync) -> float:
    t0 = time.perf_counter()
    out = fn()
    if sync is not None:
        sync(out)
    return time.perf_counter() - t0


def measure(
    fn: Callable[[], object],
    *,
    warmup: int = DEFAULT_WARMUP,
    repeats: int = DEFAULT_REPEATS,
    sync=default_sync,
) -> Measurement:
    """Time a zero-arg callable under the measurement contract."""
    if repeats < 1:
        raise ValueError("repeats must be >= 1")
    for _ in range(max(warmup, 0)):
        out = fn()
        if sync is not None:
            sync(out)
    samples = [_timed_call(fn, sync) for _ in range(repeats)]
    med, iqr = median_iqr(samples)
    return Measurement(
        median_s=med,
        iqr_s=iqr,
        min_s=float(min(samples)),
        max_s=float(max(samples)),
        samples_s=tuple(samples),
        warmup=max(warmup, 0),
        repeats=repeats,
    )


def measure_interleaved(
    fns: "Mapping[str, Callable[[], object]]",
    *,
    warmup: int = DEFAULT_WARMUP,
    repeats: int = DEFAULT_REPEATS,
    sync=default_sync,
) -> "dict[str, Measurement]":
    """Time a group of configs round-robin (drift hits all equally)."""
    if repeats < 1:
        raise ValueError("repeats must be >= 1")
    names = list(fns)
    for _ in range(max(warmup, 0)):
        for name in names:
            out = fns[name]()
            if sync is not None:
                sync(out)
    samples: dict[str, list[float]] = {name: [] for name in names}
    for _ in range(repeats):
        for name in names:
            samples[name].append(_timed_call(fns[name], sync))
    out_d = {}
    for name in names:
        med, iqr = median_iqr(samples[name])
        out_d[name] = Measurement(
            median_s=med,
            iqr_s=iqr,
            min_s=float(min(samples[name])),
            max_s=float(max(samples[name])),
            samples_s=tuple(samples[name]),
            warmup=max(warmup, 0),
            repeats=repeats,
        )
    return out_d
