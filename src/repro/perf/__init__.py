"""repro.perf — performance-regression subsystem (DESIGN.md §9).

The perf twin of ``repro.verify``: bench suites run under one enforced
timing discipline (``measure``), results are normalized against this
machine's calibrated roofline (``normalize``), judged against committed
``BENCH_<suite>.json`` baselines (``guard``), and gated in CI by
``tools/perfguard.py``.
"""

from repro.perf.guard import (
    CaseVerdict,
    classify,
    gate_ok,
    judge,
    json_report,
    markdown_report,
    summarize,
)
from repro.perf.measure import (
    Measurement,
    measure,
    measure_interleaved,
    median_iqr,
)
from repro.perf.normalize import Workload, host_hw, normalize, roofline_s
from repro.perf.runner import (
    record_from_measurement,
    run_case,
    run_cases,
    run_suite,
)
from repro.perf.schema import (
    PerfCase,
    PerfRecord,
    baseline_path,
    build_baseline,
    load_baseline,
    parse_csv_row,
    reference_entry,
    save_baseline,
    validate_csv,
)
from repro.perf.suites import SUITE_NAMES, cases_for

__all__ = [
    "CaseVerdict",
    "Measurement",
    "PerfCase",
    "PerfRecord",
    "SUITE_NAMES",
    "Workload",
    "baseline_path",
    "build_baseline",
    "cases_for",
    "classify",
    "gate_ok",
    "host_hw",
    "judge",
    "json_report",
    "load_baseline",
    "markdown_report",
    "measure",
    "measure_interleaved",
    "median_iqr",
    "normalize",
    "parse_csv_row",
    "record_from_measurement",
    "reference_entry",
    "roofline_s",
    "run_case",
    "run_cases",
    "run_suite",
    "save_baseline",
    "summarize",
    "validate_csv",
]
