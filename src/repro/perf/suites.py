"""The gated perf scenarios, one registry per bench suite (DESIGN.md §9).

Each entry mirrors an existing ``benchmarks/`` suite — ``engine``,
``sortd``, ``kernels``, ``netsim``, ``verify``, ``fleet`` — but pinned to a small,
deterministic slice sized for a CI gate: the point is a *stable judged
number per case*, not figure-quality coverage (that stays in
``benchmarks/run.py``).  Every case builds its inputs and warms its
executables inside ``setup`` so the timed call measures steady-state work
only, and every RNG draw is seeded.

Work models (``Workload``) are honest lower bounds — inputs read once,
outputs written once, ``n·log2(n)`` comparison "flops" for a sort — so
``pct_of_roofline`` is comparable across cases and the normalized ratio is
portable across hosts (see ``repro.perf.normalize``).  The netsim suite
has no bytes-moved model (its cost is simulator events), so it opts out
and is judged on raw seconds, machine-local by declaration.
"""

from __future__ import annotations

import math

import numpy as np

from repro.perf.normalize import Workload
from repro.perf.schema import PerfCase

SUITE_NAMES = (
    "engine", "sortd", "kernels", "netsim", "verify", "fleet", "faults",
    "workloads",
)


def _sort_workload(n: int, itemsize: int) -> Workload:
    return Workload(
        bytes_moved=2.0 * n * itemsize,
        flops=float(n) * math.log2(max(n, 2)),
    )


# --- engine ---------------------------------------------------------------


def _engine_setup(dist: str, n: int, dtype: str):
    def setup():
        from repro.core import SortEngine
        from repro.data.distributions import make_array

        eng = SortEngine()
        x = make_array(dist, n, seed=n, dtype=np.dtype(dtype))
        return lambda: eng.sort(x)

    return setup


def engine_cases(*, smoke: bool = True) -> "list[PerfCase]":
    cells = [("random", 65536, "int32", True), ("dupes", 65536, "int32", True)]
    if not smoke:
        cells += [
            ("random", 262144, "int32", False),
            ("local", 65536, "int32", False),
            ("random", 65536, "uint32", False),
        ]
    return [
        PerfCase(
            suite="engine",
            key=f"sort/{dist}/{n}/{dtype}",
            setup=_engine_setup(dist, n, dtype),
            workload=_sort_workload(n, np.dtype(dtype).itemsize),
            smoke=in_smoke,
        )
        for dist, n, dtype, in_smoke in cells
    ]


# --- sortd ----------------------------------------------------------------


def _segments_setup(batch: int, lo: int, hi: int, dtype: str):
    def setup():
        from repro.core import SortEngine

        eng = SortEngine()
        rng = np.random.default_rng(7)
        lens = rng.integers(lo, hi, batch)
        arrs = [rng.integers(0, 1 << 30, n).astype(dtype) for n in lens]
        flat = np.concatenate(arrs)
        seg_lens = [int(a.size) for a in arrs]
        return lambda: eng.sort_segments(flat, seg_lens)

    return setup


def sortd_cases(*, smoke: bool = True) -> "list[PerfCase]":
    cells = [(64, True)]
    if not smoke:
        cells += [(256, False)]
    out = []
    for batch, in_smoke in cells:
        # mean segment length (lo+hi)/2 sizes the work model; the draw is
        # seeded, so the realized total is fixed per case anyway.
        lo, hi = 256, 2048
        total = batch * (lo + hi) // 2
        out.append(PerfCase(
            suite="sortd",
            key=f"sort_segments/B{batch}/int32",
            setup=_segments_setup(batch, lo, hi, "int32"),
            workload=_sort_workload(total, 4),
            smoke=in_smoke,
        ))
    return out


# --- kernels --------------------------------------------------------------


def _jnp_sort_setup(n: int):
    def setup():
        import jax
        import jax.numpy as jnp

        from repro.data.distributions import make_array

        f = jax.jit(jnp.sort)
        x = jnp.asarray(make_array("random", n, seed=n))
        return lambda: f(x)

    return setup


def _local_sort_setup(n: int):
    def setup():
        import jax.numpy as jnp

        from repro.data.distributions import make_array
        from repro.kernels import ops

        x = jnp.asarray(make_array("random", n, seed=n))
        return lambda: ops.local_sort(x)

    return setup


def _rowsort_setup(backend: str, B: int, L: int):
    """One segment-path row backend on a fixed full-range int32 batch:
    ``vmap`` jits the vmapped XLA sort, the pallas backends call the fused
    batched kernel (``repro.kernels.batched``) directly — same candidates
    the engine's ``choose_row_backend`` autotune races."""

    def setup():
        import jax
        import jax.numpy as jnp

        from repro.kernels import batched, ops

        rng = np.random.default_rng(L)
        info = np.iinfo(np.int32)
        x = jnp.asarray(rng.integers(info.min, info.max, (B, L), dtype=np.int32))
        if backend == "vmap":
            f = jax.jit(jax.vmap(jnp.sort))
            return lambda: f(x)
        lens = jnp.full((B,), L, jnp.int32)
        method = {"pallas": "bitonic", "pallas2op": "bitonic2op"}[backend]
        interpret = ops._auto_interpret(None)
        return lambda: batched.batched_row_sort(
            x, lens, method=method, interpret=interpret
        )

    return setup


def kernels_cases(*, smoke: bool = True) -> "list[PerfCase]":
    # The interpreted Pallas paths cost orders of magnitude more than the
    # work model and a python-interpreted call swings run to run, so those
    # cases carry the wide netsim-style band.
    wide = {"lower": 0.70, "upper": 1.50}
    cases = [
        PerfCase(
            suite="kernels",
            key="jnp_sort/65536",
            setup=_jnp_sort_setup(65536),
            workload=_sort_workload(65536, 4),
        ),
        PerfCase(
            suite="kernels",
            key="bitonic_interpret/4096",
            setup=_local_sort_setup(4096),
            # the ratio still gates, but the python-interpreted call
            # swings ~2x run to run — wide band
            workload=_sort_workload(4096, 4),
            **wide,
        ),
        # The row-backend A/B the engine's autotune stands on, persisted as
        # paired baseline rows: the committed raw_s ratio documents which
        # backend wins the B64xL1024 serving bucket on this host, and
        # perfguard re-judges each side on every gate run
        # (benchmarks/bench_kernels.py runs the same pair interleaved).
        PerfCase(
            suite="kernels",
            key="rowsort_vmap/B64xL1024",
            setup=_rowsort_setup("vmap", 64, 1024),
            workload=_sort_workload(64 * 1024, 4),
            **wide,
        ),
        PerfCase(
            suite="kernels",
            key="rowsort_pallas/B64xL1024",
            setup=_rowsort_setup("pallas", 64, 1024),
            workload=_sort_workload(64 * 1024, 4),
            **wide,
        ),
    ]
    if not smoke:
        cases += [
            PerfCase(
                suite="kernels",
                key="jnp_sort/262144",
                setup=_jnp_sort_setup(262144),
                workload=_sort_workload(262144, 4),
                smoke=False,
            ),
            PerfCase(
                suite="kernels",
                key="rowsort_pallas2op/B64xL1024",
                setup=_rowsort_setup("pallas2op", 64, 1024),
                workload=_sort_workload(64 * 1024, 4),
                smoke=False,
                **wide,
            ),
        ]
    return cases


# --- netsim ---------------------------------------------------------------


def _netsim_setup(dims: tuple, chunk_elems: int):
    def setup():
        from repro.net.report import netsim_report

        return lambda: netsim_report(dims=dims, chunk_elems=chunk_elems)

    return setup


def netsim_cases(*, smoke: bool = True) -> "list[PerfCase]":
    cells = [((1,), 256, True)]
    if not smoke:
        cells += [((1, 2), 1024, False)]
    return [
        PerfCase(
            suite="netsim",
            key=f"report/d{'-'.join(map(str, dims))}/chunk{chunk}",
            setup=_netsim_setup(dims, chunk),
            workload=None,  # event-loop cost; raw-seconds fallback
            # Raw seconds on a pure-python event loop swing ~2x run to
            # run (GC, allocator state); the band is wide by declaration.
            lower=0.70,
            upper=1.50,
            smoke=in_smoke,
        )
        for dims, chunk, in_smoke in cells
    ]


# --- fleet ----------------------------------------------------------------


def _fleet_loop_setup(workers: "int | None", n_req: int, clients: int):
    """Closed-loop drive of a persistent warm service; ``workers=None``
    means the single-Sortd baseline (shipped default config)."""

    def setup():
        from repro.core import SortEngine
        from repro.serve.fleet import FleetConfig, SortdFleet
        from repro.serve.fleet.loadgen import drive_closed_loop, request_mix
        from repro.serve.sortd import Sortd, SortdConfig

        reqs = request_mix(n_req, seed=11)
        if workers is None:
            svc = Sortd(SortEngine(), SortdConfig(max_queue=4096))
        else:
            # Lax heartbeat: on a 1-core host the workers' cold first
            # flushes (jit compiles) contend and can each stall >1s; the
            # case measures the steady-state loop, not failover, so a
            # compile pause must not get a worker declared dead mid-warmup.
            svc = SortdFleet(
                FleetConfig(workers=workers, heartbeat_timeout_s=10.0)
            )
        # warm every bucket's executable on every worker; the service stays
        # live across the timed repeats (daemon threads, process-lifetime)
        drive_closed_loop(svc.submit, request_mix(60, seed=3), clients=clients)
        return lambda: drive_closed_loop(svc.submit, reqs, clients=clients)

    return setup


def fleet_cases(*, smoke: bool = True) -> "list[PerfCase]":
    # Paired cases on the SAME mix/clients: the baseline file's raw_s
    # ratio (single / w4) documents the fleet's ≥2x scaling contract at
    # c=2, and perfguard re-judges each side on every gate run.  Timing is
    # cross-thread scheduling, not device work — no honest bytes/flops
    # model — so the cases opt out of normalization and carry the wide
    # netsim-style band.
    n_req, clients = (80, 2) if smoke else (240, 2)
    band = {"lower": 0.70, "upper": 1.50}
    return [
        PerfCase(
            suite="fleet",
            key=f"closed/single/c{clients}",
            setup=_fleet_loop_setup(None, n_req, clients),
            workload=None,
            **band,
        ),
        PerfCase(
            suite="fleet",
            key=f"closed/w4/c{clients}",
            setup=_fleet_loop_setup(4, n_req, clients),
            workload=None,
            **band,
        ),
    ]


# --- faults ---------------------------------------------------------------


def _fault_predict_setup(d_h: int, n: int):
    """The degraded-plan pricing machinery end to end: schedule rebuild
    under the faulted router + both simulator accountings (the work
    ``SortEngine._comm_price`` does once per (bucket, scenario))."""

    def setup():
        from repro.core.topology import OHHCTopology
        from repro.net.faults import FaultScenario, predicted_slowdown

        topo = OHHCTopology(d_h, "full")
        sc = FaultScenario.optical_link_down(1)
        chunk = max(1, n // topo.total_procs)

        def run():
            predicted_slowdown(topo, sc, chunk_sizes=chunk, barrier=True)
            predicted_slowdown(topo, sc, chunk_sizes=chunk, barrier=False)

        return run

    return setup


def _fault_sort_setup(n: int, dtype: str):
    """Steady-state degraded serving: a warm engine with an active fault
    scenario sorting on the re-priced sim path (plan + comm caches hot, so
    the timed call is the sort itself — the §11 contract that degraded
    mode costs planning once, not per request)."""

    def setup():
        from repro.core import SortEngine
        from repro.data.distributions import make_array
        from repro.net.faults import FaultScenario

        eng = SortEngine()
        eng.set_fault_scenario(FaultScenario.optical_link_down(1))
        x = make_array("random", n, seed=n, dtype=np.dtype(dtype))
        return lambda: eng.sort(x)

    return setup


def faults_cases(*, smoke: bool = True) -> "list[PerfCase]":
    # Python event-loop + rebuild cost on one side, jit sort on the other;
    # both judged raw-seconds with the wide netsim-style band (the pricing
    # case is pure-python, and the sort case's fault overhead is cache
    # lookups — normalization would just mirror the engine suite).
    band = {"lower": 0.70, "upper": 1.50}
    cases = [
        PerfCase(
            suite="faults",
            key="predict/optical_g1/d1/n65536",
            setup=_fault_predict_setup(1, 65536),
            workload=None,
            **band,
        ),
        PerfCase(
            suite="faults",
            key="sort/degraded/optical_g1/random/65536/int32",
            setup=_fault_sort_setup(65536, "int32"),
            workload=_sort_workload(65536, 4),
        ),
    ]
    return cases


# --- workloads ------------------------------------------------------------


def _topk_setup(n: int, k: int):
    def setup():
        from repro.core import SortEngine
        from repro.data.distributions import make_array

        eng = SortEngine()
        x = make_array("random", n, seed=n)
        eng.top_k(x, k)  # warm the per-(capacity, keep) executable
        return lambda: eng.top_k(x, k)

    return setup


def _fullsort_setup(n: int):
    """The full-sort half of the top-k pair — same seeded input, so the
    committed raw_s ratio IS the skip-rule margin perfguard re-judges."""

    def setup():
        from repro.core import SortEngine
        from repro.data.distributions import make_array

        eng = SortEngine()
        x = make_array("random", n, seed=n)
        eng.sort(x)
        return lambda: eng.sort(x)

    return setup


def _merge_tick_setup(n_buf: int, n_new: int):
    def setup():
        from repro.core import SortEngine
        from repro.data.distributions import make_array

        eng = SortEngine()
        buf = np.sort(make_array("random", n_buf, seed=3))
        new = make_array("random", n_new, seed=4)
        eng.merge_sorted(buf, new)
        return lambda: eng.merge_sorted(buf, new)

    return setup


def _pairs_pytree_setup(n: int):
    def setup():
        from repro.core import SortEngine
        from repro.data.distributions import make_array

        eng = SortEngine()
        keys = make_array("random", n, seed=5)
        idx = np.arange(n, dtype=np.int64)
        vals = {"idx": idx, "nested": (keys.astype(np.float64),)}
        eng.sort_pairs(keys, vals)
        return lambda: eng.sort_pairs(keys, vals)

    return setup


def _moe_dispatch_setup(dispatch: str):
    def setup():
        import jax
        import jax.numpy as jnp

        from repro.configs.base import MoEConfig, ModelConfig
        from repro.models import moe as MOE
        from repro.models.common import NO_SHARD

        cfg = ModelConfig(
            family="moe", d_model=256, dtype=jnp.bfloat16,
            moe=MoEConfig(
                num_experts=8, num_experts_per_tok=2, expert_d_ff=512,
                dispatch=dispatch, capacity_factor=1.25,
            ),
        )
        p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 512, 256), jnp.bfloat16)
        f = jax.jit(lambda x: MOE.apply_moe(p, x, cfg, NO_SHARD)[0])
        f(x).block_until_ready()
        return lambda: f(x).block_until_ready()

    return setup


def workloads_cases(*, smoke: bool = True) -> "list[PerfCase]":
    """The §12 workload layer, gated as paired rows.

    ``topk`` and ``fullsort`` share the same seeded input: the committed
    ``raw_s`` ratio between them is the skip-rule speedup the issue gates
    (top-k must beat a full sort at n≥4096, k≤n/16 — the hard fail lives
    in ``benchmarks/bench_workloads.py``; here perfguard re-judges each
    side against its own baseline every run).  Host-path ops (top-k's
    numpy head, the merge gather) are microsecond-scale python+numpy —
    raw-seconds with the wide band, no device work model.
    """
    band = {"lower": 0.70, "upper": 1.50}
    n = 65536
    cases = [
        PerfCase(
            suite="workloads",
            key=f"topk/random/{n}/k{n // 16}",
            setup=_topk_setup(n, n // 16),
            workload=None,
            **band,
        ),
        PerfCase(
            suite="workloads",
            key=f"fullsort/random/{n}",
            setup=_fullsort_setup(n),
            workload=_sort_workload(n, 4),
        ),
        PerfCase(
            suite="workloads",
            key="merge_tick/buf65536/new2048",
            setup=_merge_tick_setup(65536, 2048),
            workload=None,
            **band,
        ),
        PerfCase(
            suite="workloads",
            key="pairs_pytree/random/4096",
            setup=_pairs_pytree_setup(4096),
            workload=_sort_workload(4096, 4),
            **band,
        ),
    ]
    if not smoke:
        cases += [
            PerfCase(
                suite="workloads",
                key=f"moe_dispatch/{dispatch}/E8k2T512",
                setup=_moe_dispatch_setup(dispatch),
                workload=None,
                smoke=False,
                **band,
            )
            for dispatch in ("sorted", "argsort")
        ]
    return cases


# --- verify ---------------------------------------------------------------


def _verify_setup(dtype: str):
    def setup():
        from repro.verify import differential, grid

        scenarios = [sc for sc in grid.tier1_grid() if sc.dtype == dtype]
        engines = differential.EngineCache(devices=1)
        run = lambda: differential.run_grid(  # noqa: E731
            scenarios, keep_outputs=False, engines=engines
        )
        run()  # warm every (shape bucket, method) executable in the slice
        return run

    return setup


def _verify_workload(dtype: str) -> Workload:
    from repro.verify import grid

    total_bytes = 0.0
    total_flops = 0.0
    for sc in grid.tier1_grid():
        if sc.dtype != dtype:
            continue
        w = _sort_workload(sc.n, np.dtype(sc.dtype).itemsize)
        total_bytes += w.bytes_moved
        total_flops += w.flops
    return Workload(bytes_moved=total_bytes, flops=total_flops)


def verify_cases(*, smoke: bool = True) -> "list[PerfCase]":
    dtypes = ["int32"] if smoke else ["int32", "uint32"]
    return [
        PerfCase(
            suite="verify",
            key=f"tier1/{dtype}",
            setup=_verify_setup(dtype),
            workload=_verify_workload(dtype),
            smoke=dtype == "int32",
        )
        for dtype in dtypes
    ]


SUITES = {
    "engine": engine_cases,
    "sortd": sortd_cases,
    "kernels": kernels_cases,
    "netsim": netsim_cases,
    "verify": verify_cases,
    "fleet": fleet_cases,
    "faults": faults_cases,
    "workloads": workloads_cases,
}


def cases_for(suite: str, *, smoke: bool = True) -> "list[PerfCase]":
    if suite not in SUITES:
        raise KeyError(f"unknown perf suite {suite!r}; choose from {SUITE_NAMES}")
    return SUITES[suite](smoke=smoke)
