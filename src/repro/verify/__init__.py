"""repro.verify — paper-grid differential conformance & regression subsystem.

The paper's evaluation is an experiment grid — "different OHHC dimensions,
different integer array types and different array sizes" — and this package
turns that grid into an executable, CI-enforced contract (DESIGN.md §7):

* :mod:`repro.verify.grid`          — parameterized scenario axes + pruning
* :mod:`repro.verify.differential`  — every scenario vs the ``np.sort``
  oracle, plus cross-path agreement checks
* :mod:`repro.verify.properties`    — metamorphic checks and fault-scenario
  stress via ``repro.net.faults`` degraded schedules
* :mod:`repro.verify.baseline`      — per-scenario JSON baselines with
  drift detection (a plan-policy change must be an explicit baseline
  update, never a silent flip)

CLI entry point: ``python tools/verify.py --smoke`` (see tools/verify.py).
"""

from repro.verify.grid import (
    DIMS,
    DTYPES,
    SIZE_BUCKETS,
    WORKLOAD_OPS,
    FaultCell,
    OpScenario,
    Scenario,
    fault_grid,
    full_grid,
    op_prune_reason,
    op_smoke_grid,
    op_tier1_grid,
    prune_reason,
    smoke_grid,
    tier1_grid,
)
from repro.verify.differential import (
    ScenarioResult,
    cross_check,
    run_fault_grid,
    run_fault_scenario,
    run_grid,
    run_op_grid,
    run_op_scenario,
    run_scenario,
)
from repro.verify.properties import (
    fault_replay,
    metamorphic_checks,
    pairs_pairing_check,
)
from repro.verify.baseline import (
    DriftReport,
    build_baseline,
    diff_baselines,
    load_baseline,
    save_baseline,
)

__all__ = [
    "DIMS",
    "DTYPES",
    "SIZE_BUCKETS",
    "WORKLOAD_OPS",
    "FaultCell",
    "OpScenario",
    "Scenario",
    "fault_grid",
    "full_grid",
    "op_prune_reason",
    "op_smoke_grid",
    "op_tier1_grid",
    "prune_reason",
    "smoke_grid",
    "tier1_grid",
    "ScenarioResult",
    "cross_check",
    "run_fault_grid",
    "run_fault_scenario",
    "run_grid",
    "run_op_grid",
    "run_op_scenario",
    "run_scenario",
    "fault_replay",
    "metamorphic_checks",
    "pairs_pairing_check",
    "DriftReport",
    "build_baseline",
    "diff_baselines",
    "load_baseline",
    "save_baseline",
]
