"""Differential execution: every grid scenario vs the ``np.sort`` oracle
(DESIGN.md §7).

Each :class:`~repro.verify.grid.Scenario` is forced down its declared
(path, method) via an explicit :class:`~repro.core.engine.SortPlan` — the
same calling convention ``benchmarks/bench_engine.py`` uses for its fixed
baselines — so the grid exercises the executors directly rather than
whatever ``choose_plan`` would have picked.  Engines are cached per
(topology, mesh-shape) so the warm jit cache works *for* the sweep: two
scenarios in the same shape bucket share one executable.

Checks per scenario:

* **oracle**     — output equals ``np.sort(input)`` exactly, dtype preserved;
* **conservation** — the executor's element accounting (``counts_sum``)
  matches ``n`` (no silent capacity drops);
* **cross-path** — :func:`cross_check` then asserts byte-equality between
  every pair of paths/methods that sorted the same input array, which
  catches oracle *and* comparison bugs that a single-path check can hide.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.core import OHHCTopology, SortEngine, SortPlan, autotune_capacity
from repro.verify.grid import (
    FAULT_IMPOSSIBLE,
    FaultCell,
    OpScenario,
    Scenario,
    SegmentScenario,
)


@dataclasses.dataclass
class ScenarioResult:
    """Outcome of one scenario run.  ``output`` is held only for the
    in-memory cross-check; baselines persist the stable fields."""

    scenario: Scenario
    status: str  # 'pass' | 'fail'
    detail: str
    path: str
    method: str
    capacity: int | None
    retries: int
    counts_sum: int | None
    elapsed_s: float
    output: np.ndarray | None = None

    @property
    def scenario_id(self) -> str:
        return self.scenario.scenario_id


class EngineCache:
    """One SortEngine per (d_h, variant, needs-mesh) — shared jit caches."""

    def __init__(self, *, devices: int = 1):
        self.devices = int(devices)
        self._engines: dict[tuple, SortEngine] = {}
        self._meshes: dict[int, object] = {}

    def mesh(self, axes: int):
        import jax
        from jax.sharding import Mesh

        if axes not in self._meshes:
            devs = np.array(jax.devices()[: self.devices])
            if axes >= 2:
                self._meshes[axes] = Mesh(
                    devs.reshape(2, -1), ("pod", "data")
                )
            else:
                self._meshes[axes] = Mesh(devs, ("data",))
        return self._meshes[axes]

    def segment_engine(self) -> SortEngine:
        """The shared single-box engine the segment cells run on (d_h=1 —
        the segment path's method is forced per cell, so topology only
        sizes the never-used bucket fallback)."""
        key = (1, "full", False, 1)
        eng = self._engines.get(key)
        if eng is None:
            eng = self._engines[key] = SortEngine(OHHCTopology(1, "full"))
        return eng

    def fault_engine(self, cell: FaultCell) -> SortEngine:
        """One engine per fault-grid topology, *shared across fault
        classes* — the degraded grid deliberately switches scenarios on a
        warm engine so stale-plan bugs (DESIGN.md §11) would surface as
        wrong cells here, not just in the unit tests."""
        key = ("fault", cell.d_h, cell.variant)
        eng = self._engines.get(key)
        if eng is None:
            eng = self._engines[key] = SortEngine(
                OHHCTopology(cell.d_h, cell.variant)
            )
        return eng

    def engine_for(self, sc: Scenario) -> SortEngine:
        mesh_axes = 2 if (sc.path == "dist" and sc.method == "hier") else 1
        key = (sc.d_h, sc.variant, sc.path == "dist", mesh_axes)
        eng = self._engines.get(key)
        if eng is None:
            topo = OHHCTopology(sc.d_h, sc.variant)
            if sc.path == "dist":
                mesh = self.mesh(mesh_axes)
                names = mesh.axis_names
                eng = SortEngine(topo, mesh=mesh, axis_names=names)
            else:
                eng = SortEngine(topo)
            self._engines[key] = eng
        return eng


def forced_plan(eng: SortEngine, sc: Scenario, x: np.ndarray) -> SortPlan:
    """Pin the scenario's (path, method); capacity still comes from the
    engine's measured autotune so the grid validates the capacity model too."""
    if sc.path == "host":
        return SortPlan("host", sc.method, None, None, "verify grid")
    if sc.path == "dist":
        return SortPlan("dist", sc.method, None, None, "verify grid")
    from repro.kernels import ops

    stats = eng.stats(x)
    padded = ops.bucketed_length(x.size)
    cap = autotune_capacity(stats, sc.method, eng.topo.total_procs, padded)
    return SortPlan("sim", sc.method, cap, padded, "verify grid")


def run_scenario(
    sc: Scenario, engines: EngineCache, *, keep_output: bool = True
) -> ScenarioResult:
    """Execute one scenario against the oracle."""
    x = sc.make_input()
    oracle = np.sort(x)
    eng = engines.engine_for(sc)
    t0 = time.perf_counter()
    try:
        plan = forced_plan(eng, sc, x)
        out = eng.sort(x, plan=plan)
    except Exception as e:  # an executor crash is a finding, not an abort
        return ScenarioResult(
            sc, "fail", f"error: {type(e).__name__}: {e}", sc.path, sc.method,
            None, 0, None, time.perf_counter() - t0,
        )
    elapsed = time.perf_counter() - t0
    report = eng.last_report or {}
    capacity = report.get("capacity_used", plan.capacity)
    retries = int(report.get("overflow_retries", 0))
    counts_sum = report.get("counts_sum")
    counts_sum = int(counts_sum) if counts_sum is not None else None

    out = np.asarray(out)
    if out.dtype != x.dtype:
        status, detail = "fail", f"dtype changed: {x.dtype} -> {out.dtype}"
    elif out.shape != oracle.shape:
        status, detail = "fail", f"shape changed: {oracle.shape} -> {out.shape}"
    elif not np.array_equal(out, oracle):
        bad = int(np.flatnonzero(out != oracle)[0])
        status = "fail"
        detail = (
            f"oracle mismatch at index {bad}: got {out[bad]!r}, "
            f"want {oracle[bad]!r}"
        )
    elif counts_sum is not None and counts_sum != x.size:
        status, detail = "fail", f"element accounting: counts_sum={counts_sum} != n={x.size}"
    else:
        status, detail = "pass", ""
    return ScenarioResult(
        sc, status, detail, sc.path, sc.method, capacity, retries,
        counts_sum, elapsed, out if keep_output else None,
    )


def run_segment_scenario(
    sc: SegmentScenario, engines: EngineCache, *, keep_output: bool = True
) -> ScenarioResult:
    """One segmented-batch cell: force the row-sort method through
    ``sort_segments(plan=...)`` and oracle every row against ``np.sort``.

    The stored output is the concatenation of the sorted segments, so the
    cross-check asserts byte-agreement between the vmapped XLA backend and
    both fused Pallas variants on the same batch.
    """
    from repro.kernels import ops

    flat, lens = sc.make_batch()
    eng = engines.segment_engine()
    padded_n = ops.bucketed_length(max(lens) if lens else 1)
    plan = SortPlan("sim", sc.method, None, padded_n, "verify segment grid")
    t0 = time.perf_counter()
    try:
        outs = eng.sort_segments(flat, lens, plan=plan)
    except Exception as e:  # an executor crash is a finding, not an abort
        return ScenarioResult(
            sc, "fail", f"error: {type(e).__name__}: {e}", "sim", sc.method,
            None, 0, None, time.perf_counter() - t0,
        )
    elapsed = time.perf_counter() - t0
    report = eng.last_report or {}
    retries = int(report.get("overflow_retries", 0))
    method = getattr(report.get("plan"), "method", sc.method)
    status, detail = "pass", ""
    oracle_rows = np.split(flat, np.cumsum(lens)[:-1]) if lens else []
    for i, (seg, n) in enumerate(zip(outs, lens)):
        seg = np.asarray(seg)
        want = np.sort(oracle_rows[i])
        if seg.dtype != flat.dtype:
            status, detail = "fail", f"row {i}: dtype {flat.dtype} -> {seg.dtype}"
            break
        if seg.shape != (n,):
            status, detail = "fail", f"row {i}: length {seg.size} != {n}"
            break
        if not np.array_equal(seg, want):
            bad = int(np.flatnonzero(seg != want)[0])
            status = "fail"
            detail = (
                f"row {i} oracle mismatch at {bad}: got {seg[bad]!r}, "
                f"want {want[bad]!r}"
            )
            break
    out_flat = (
        np.concatenate([np.asarray(o) for o in outs]) if lens else np.zeros(0)
    )
    return ScenarioResult(
        sc, status, detail, "sim", method, None, retries, None, elapsed,
        out_flat if keep_output else None,
    )


def run_segment_grid(
    scenarios: "Sequence[SegmentScenario]",
    *,
    keep_outputs: bool = True,
    progress: "Callable[[ScenarioResult], None] | None" = None,
    engines: "EngineCache | None" = None,
) -> list[ScenarioResult]:
    """Run every segment cell (same contract as :func:`run_grid`)."""
    if engines is None:
        engines = EngineCache(devices=1)
    results = []
    for sc in scenarios:
        r = run_segment_scenario(sc, engines, keep_output=keep_outputs)
        results.append(r)
        if progress is not None:
            progress(r)
    return results


def run_fault_scenario(
    cell: FaultCell, engines: EngineCache, *, keep_output: bool = True
) -> ScenarioResult:
    """One degraded-topology cell: set the engine's fault scenario, force
    the requested (path, method), and oracle the result.

    The pins beyond the oracle (DESIGN.md §11):

    * a degraded-but-possible scenario must *execute* on the requested
      path with the plan annotated (``plan.fault`` + predicted slowdown);
    * an impossible scenario (``FAULT_IMPOSSIBLE``) forced onto ``sim``
      must come back on the typed host fallback — never an error, never
      a wrong answer;
    * the recorded ``path`` is the *executed* one, so the committed
      baseline pins which rung of the fallback ladder every cell lands on.
    """
    x = cell.make_input()
    oracle = np.sort(x)
    eng = engines.fault_engine(cell)
    t0 = time.perf_counter()
    try:
        scenario = cell.scenario(eng.topo)
        eng.set_fault_scenario(scenario)
        plan = forced_plan(eng, cell, x)
        out = eng.sort(x, plan=plan)
    except Exception as e:  # an executor crash is a finding, not an abort
        return ScenarioResult(
            cell, "fail", f"error: {type(e).__name__}: {e}", cell.path,
            cell.method, None, 0, None, time.perf_counter() - t0,
        )
    finally:
        eng.set_fault_scenario(None)  # engines are shared; never leak faults
    elapsed = time.perf_counter() - t0
    report = eng.last_report or {}
    executed = report.get("plan")
    path = getattr(executed, "path", cell.path)
    method = getattr(executed, "method", cell.method)
    fault_name = getattr(executed, "fault", None)
    capacity = report.get("capacity_used", plan.capacity)
    retries = int(report.get("overflow_retries", 0))
    counts_sum = report.get("counts_sum")
    counts_sum = int(counts_sum) if counts_sum is not None else None

    out = np.asarray(out)
    impossible = cell.fault in FAULT_IMPOSSIBLE
    if out.dtype != x.dtype:
        status, detail = "fail", f"dtype changed: {x.dtype} -> {out.dtype}"
    elif out.shape != oracle.shape:
        status, detail = "fail", f"shape changed: {oracle.shape} -> {out.shape}"
    elif not np.array_equal(out, oracle):
        bad = int(np.flatnonzero(out != oracle)[0])
        status = "fail"
        detail = (
            f"oracle mismatch at index {bad}: got {out[bad]!r}, "
            f"want {oracle[bad]!r}"
        )
    elif counts_sum is not None and counts_sum != x.size:
        status, detail = "fail", f"element accounting: counts_sum={counts_sum} != n={x.size}"
    elif scenario is not None and fault_name != scenario.name:
        status = "fail"
        detail = f"plan not annotated: plan.fault={fault_name!r}, want {scenario.name!r}"
    elif impossible and cell.path == "sim" and path != "host":
        status = "fail"
        detail = f"impossible scenario executed on {path!r}, want host fallback"
    elif scenario is not None and not impossible and path != cell.path:
        status = "fail"
        detail = f"possible scenario bumped off {cell.path!r} onto {path!r}"
    else:
        status, detail = "pass", ""
    return ScenarioResult(
        cell, status, detail, path, method, capacity, retries,
        counts_sum, elapsed, out if keep_output else None,
    )


def run_fault_grid(
    cells: "Sequence[FaultCell]",
    *,
    keep_outputs: bool = True,
    progress: "Callable[[ScenarioResult], None] | None" = None,
    engines: "EngineCache | None" = None,
) -> list[ScenarioResult]:
    """Run every degraded-grid cell (same contract as :func:`run_grid`)."""
    if engines is None:
        engines = EngineCache(devices=1)
    results = []
    for cell in cells:
        r = run_fault_scenario(cell, engines, keep_output=keep_outputs)
        results.append(r)
        if progress is not None:
            progress(r)
    return results


def run_grid(
    scenarios: Sequence[Scenario],
    *,
    devices: int = 1,
    keep_outputs: bool = True,
    progress: "Callable[[ScenarioResult], None] | None" = None,
    engines: "EngineCache | None" = None,
) -> list[ScenarioResult]:
    """Run every scenario (pre-pruned ones are the caller's business —
    anything handed in is executed) and return results in grid order.

    Pass ``engines`` to reuse warm jit caches across sweeps (e.g. a
    warm-up pass before a timed pass — ``benchmarks/bench_verify.py``).
    """
    if engines is None:
        engines = EngineCache(devices=devices)
    results = []
    for sc in scenarios:
        r = run_scenario(sc, engines, keep_output=keep_outputs)
        results.append(r)
        if progress is not None:
            progress(r)
    return results


def _op_pytree_payload(x: np.ndarray) -> dict:
    """The conformance payload for ``pairs_pytree`` cells: a nested
    dict/tuple with mixed dtypes (64-bit, float, sub-byte-range int) so the
    leaf gather is exercised on every byte width at once."""
    idx = np.arange(x.size, dtype=np.int64)
    return {
        "idx": idx,
        "nested": (x.astype(np.float64), (idx % 251).astype(np.int8)),
    }


def run_op_scenario(
    sc: OpScenario, engines: EngineCache, *, keep_output: bool = True
) -> ScenarioResult:
    """Execute one workload-op cell (DESIGN.md §12) against its oracle.

    Per-op oracle:

    * ``sort``         — ``np.sort(x)`` (the baseline the others share);
    * ``top_k``        — ``np.sort(x)[:k]``, and the plan's ``reason`` must
      carry the ``skipped=`` bucket accounting the issue pins;
    * ``pairs_pytree`` — keys equal ``np.sort(x)``; the ``idx`` leaf is a
      valid permutation and every other leaf is byte-identical to
      ``leaf[perm]`` (the gather contract);
    * ``merge``        — host-sorted prefix + chunked ``merge_sorted``
      folds of the remainder equals ``np.sort(x)``.

    The stored ``output`` is always the fully-sorted key view the op
    implies (the head for top-k), so cells sharing a ``group_id`` —
    ``sort``/``pairs_pytree``/``merge`` on the same input — byte-compare
    against each other in :func:`cross_check`.
    """
    x = sc.make_input()
    oracle = np.sort(x)
    eng = engines.segment_engine()
    t0 = time.perf_counter()
    try:
        if sc.op == "sort":
            out = np.asarray(eng.sort(x))
            want = oracle
        elif sc.op == "top_k":
            out = np.asarray(eng.top_k(x, sc.k))
            want = oracle[: sc.k]
        elif sc.op == "pairs_pytree":
            keys_s, vals_s = eng.sort_pairs(x, _op_pytree_payload(x))
            out = np.asarray(keys_s)
            want = oracle
        elif sc.op == "merge":
            split = 2 * x.size // 3
            buf = np.sort(x[:split])
            rest = x[split:]
            for chunk in np.array_split(rest, 3):
                buf = eng.merge_sorted(buf, chunk)
            out = np.asarray(buf)
            want = oracle
        else:  # pragma: no cover - pruned upstream
            raise ValueError(f"unknown op {sc.op!r}")
    except Exception as e:  # an executor crash is a finding, not an abort
        return ScenarioResult(
            sc, "fail", f"error: {type(e).__name__}: {e}", sc.path, sc.method,
            None, 0, None, time.perf_counter() - t0,
        )
    elapsed = time.perf_counter() - t0
    report = eng.last_report or {}
    plan = report.get("plan")
    path = plan.path if plan is not None else "host"
    method = plan.method if plan is not None else sc.op
    capacity = report.get("capacity_used")
    capacity = int(capacity) if capacity is not None else None
    retries = int(report.get("overflow_retries", 0))
    counts_sum = report.get("counts_sum")
    counts_sum = int(counts_sum) if counts_sum is not None else None

    status, detail = "pass", ""
    if out.dtype != x.dtype:
        status, detail = "fail", f"dtype changed: {x.dtype} -> {out.dtype}"
    elif out.shape != want.shape:
        status, detail = "fail", f"shape changed: {want.shape} -> {out.shape}"
    elif not np.array_equal(out, want):
        bad = int(np.flatnonzero(out != want)[0])
        status = "fail"
        detail = (
            f"oracle mismatch at index {bad}: got {out[bad]!r}, "
            f"want {want[bad]!r}"
        )
    elif sc.op == "top_k":
        if plan is None or "skipped=" not in (plan.reason or ""):
            status = "fail"
            detail = (
                "top_k plan reason lacks skipped-bucket accounting: "
                f"{plan.reason if plan is not None else None!r}"
            )
        elif int(report.get("kept_count", 0)) < sc.k:
            status = "fail"
            detail = (
                f"kept_count={report.get('kept_count')} < k={sc.k} "
                "after retries — cut under-covers the head"
            )
    elif sc.op == "pairs_pytree":
        perm = np.asarray(vals_s["idx"])
        f64, i8 = vals_s["nested"]
        if not np.array_equal(np.sort(perm), np.arange(x.size)):
            status, detail = "fail", "payload idx leaf is not a permutation"
        elif np.asarray(f64).tobytes() != x.astype(np.float64)[perm].tobytes():
            status, detail = "fail", "float64 leaf not gathered by idx perm"
        elif np.asarray(i8).tobytes() != (
            (np.arange(x.size, dtype=np.int64) % 251).astype(np.int8)[perm]
        ).tobytes():
            status, detail = "fail", "int8 leaf not gathered by idx perm"
    elif sc.op == "sort" and counts_sum is not None and counts_sum != x.size:
        status = "fail"
        detail = f"element accounting: counts_sum={counts_sum} != n={x.size}"
    return ScenarioResult(
        sc, status, detail, path, method, capacity, retries,
        counts_sum, elapsed, out if keep_output else None,
    )


def run_op_grid(
    scenarios: "Sequence[OpScenario]",
    *,
    keep_outputs: bool = True,
    progress: "Callable[[ScenarioResult], None] | None" = None,
    engines: "EngineCache | None" = None,
) -> list[ScenarioResult]:
    """Run every workload-op cell (same contract as :func:`run_grid`)."""
    if engines is None:
        engines = EngineCache(devices=1)
    results = []
    for sc in scenarios:
        r = run_op_scenario(sc, engines, keep_output=keep_outputs)
        results.append(r)
        if progress is not None:
            progress(r)
    return results


def cross_check(results: Sequence[ScenarioResult]) -> list[str]:
    """Pairwise differential check: all paths/methods that sorted the same
    input must produce byte-identical output, *including* scenarios that
    failed the oracle (so a divergence is reported both as the failing
    cell and as a localized path-vs-path disagreement).  Returns mismatch
    messages."""
    groups: dict[str, list[ScenarioResult]] = {}
    for r in results:
        if r.output is not None:
            groups.setdefault(r.scenario.group_id, []).append(r)
    mismatches = []
    for gid, members in groups.items():
        ref = members[0]
        for other in members[1:]:
            if not np.array_equal(ref.output, other.output):
                mismatches.append(
                    f"{gid}: {ref.scenario_id} and {other.scenario_id} disagree"
                )
    return mismatches


