"""Paper-grid scenario axes and pruning rules (DESIGN.md §7).

One :class:`Scenario` is one cell of the paper's experiment grid, extended
along every axis the repo actually implements:

* ``dtype``   — the paper's "different integer array types"
  (int8/int16/int32/int64/uint32) plus float32;
* ``dist``    — the paper's §5 input classes (``ALL_DISTRIBUTIONS``:
  random/sorted/reversed/local + the beyond-paper duplicate-heavy class);
* ``n``       — size buckets chosen to hit distinct pow2 jit shape buckets
  (including a non-power-of-two and a sub-``P`` size);
* ``d_h``/``variant`` — OHHC dimension and group variant (Table 1.1);
* ``path``/``method`` — the execution path (``sim``/``host``/``dist``) and
  its splitter method.

Invalid combinations are *pruned, not skipped silently*:
:func:`prune_reason` returns a human-readable reason string, and the CLI
report carries every pruned cell so the grid's coverage is auditable.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, Sequence

import numpy as np

from repro.data.distributions import ALL_DISTRIBUTIONS

# The paper's "different integer array types", plus float32 (§2's TPU-native
# key type).  uint64/float64 are excluded: without jax x64 they have no
# exact jit path at all, and the host path already covers 64-bit via int64.
DTYPES = ("int8", "int16", "int32", "int64", "uint32", "float32")

# Distinct pow2 shape buckets: 64 (sub-P for d_h≥2 — more buckets than
# elements), 257 (odd, pads to 512), 1024 (exact pow2), 3072 (pads to 4096).
SIZE_BUCKETS = (64, 257, 1024, 3072)

DIMS = (1, 2, 3)

PATHS = ("sim", "host", "dist")
SIM_METHODS = ("paper", "sampled")
HOST_METHODS = ("paper", "sampled")
DIST_METHODS = ("paper", "sample", "hier", "valiant")


def methods_for(path: str) -> tuple[str, ...]:
    return {"sim": SIM_METHODS, "host": HOST_METHODS, "dist": DIST_METHODS}[path]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One executable cell of the conformance grid."""

    path: str
    method: str
    dtype: str
    dist: str
    n: int
    d_h: int
    variant: str = "full"
    seed: int = 7

    @property
    def scenario_id(self) -> str:
        """Stable key used by baselines; every axis value is spelled out."""
        var = "" if self.variant == "full" else f"-{self.variant}"
        return (
            f"{self.path}/{self.method}/{self.dtype}/{self.dist}"
            f"/n{self.n}/d{self.d_h}{var}"
        )

    @property
    def group_id(self) -> str:
        """Input-identity key: scenarios sharing it sort the *same array*
        and must agree output-for-output (the differential cross-check)."""
        var = "" if self.variant == "full" else f"-{self.variant}"
        return f"{self.dtype}/{self.dist}/n{self.n}/d{self.d_h}{var}/s{self.seed}"

    def make_input(self) -> np.ndarray:
        from repro.data.distributions import make_array

        return make_array(self.dist, self.n, seed=self.seed, dtype=np.dtype(self.dtype))


def prune_reason(
    sc: Scenario, *, devices: int = 1, mesh_axes: int = 1, x64: "bool | None" = None
) -> str | None:
    """Why ``sc`` cannot run in this environment (None = runnable).

    ``devices``/``mesh_axes`` describe the available jax mesh; pruning is a
    property of (scenario, environment), never silent.  ``x64`` pins the
    64-bit-key rule: ``None`` autodetects the ambient jax config; the
    baseline-facing grids pass ``False`` so the committed smoke baseline's
    cell set never depends on ``JAX_ENABLE_X64`` (running with x64 on then
    merely *skips* those cells — it can never execute a downcasting one).
    """
    if x64 is None:
        from repro.core.engine import x64_enabled

        x64 = x64_enabled()
    if sc.path not in PATHS:
        return f"unknown path {sc.path!r}"
    if sc.method not in methods_for(sc.path):
        return f"method {sc.method!r} invalid for path {sc.path!r}"
    if np.dtype(sc.dtype).itemsize == 8 and sc.path != "host" and not x64:
        return "64-bit keys downcast on jit paths without jax x64; host covers this cell"
    if sc.path == "dist":
        if devices < 2:
            return "dist path needs a >1-device mesh"
        if sc.method == "hier" and mesh_axes < 2:
            return "hier method needs a 2-axis (pod, data) mesh"
        if sc.n < devices:
            return "dist path needs at least one element per shard"
    return None


def _grid(
    paths: Sequence[str],
    dtypes: Sequence[str],
    dists: Sequence[str],
    sizes: Sequence[int],
    dims: Sequence[int],
    variants: Sequence[str] = ("full",),
) -> Iterator[Scenario]:
    for path, d_h, variant, dtype, dist, n in itertools.product(
        paths, dims, variants, dtypes, dists, sizes
    ):
        for method in methods_for(path):
            yield Scenario(path, method, dtype, dist, n, d_h, variant)


def full_grid(*, devices: int = 1, mesh_axes: int = 1) -> list[Scenario]:
    """Every runnable scenario of the full paper grid (pruned cells removed;
    use :func:`pruned_cells` for the audit list)."""
    scenarios = list(
        _grid(PATHS, DTYPES, ALL_DISTRIBUTIONS, SIZE_BUCKETS, DIMS)
    )
    # The half-group variant (Table 1.1's G = P/2 column) at d_h=1: the
    # other topology family, exercised on the single-box paths.
    scenarios += list(
        _grid(("sim", "host"), DTYPES, ALL_DISTRIBUTIONS, (1024,), (1,), ("half",))
    )
    return [
        sc
        for sc in scenarios
        if prune_reason(sc, devices=devices, mesh_axes=mesh_axes) is None
    ]


def smoke_grid(*, devices: int = 1, mesh_axes: int = 1) -> list[Scenario]:
    """The pruned CI grid: every axis value covered, ≥100 scenarios, small
    sizes only so the whole sweep stays in CI's fast lane.

    Structure: the complete dtype × dist × method plane for sim+host at
    d_h=1 over two sizes, plus dimension rows (d_h ∈ {2,3}), a half-variant
    row, and — when a mesh exists — a dist row per method.
    """
    scenarios: list[Scenario] = []
    # The dense plane: both single-box paths, all dtypes, all input classes.
    scenarios += _grid(("sim", "host"), DTYPES, ALL_DISTRIBUTIONS, (257, 1024), (1,))
    # Dimension axis: higher d_h on the jit path (P = 144 / 576), including
    # the n < P cell where most buckets stay empty.
    scenarios += _grid(("sim",), ("int32",), ("random", "dupes"), (64, 1024), (2, 3))
    # Variant axis: the half-group topology.
    scenarios += _grid(
        ("sim", "host"), ("int32", "uint32"), ("random", "local"), (1024,), (1,), ("half",)
    )
    # Mesh axis (only when the environment has one — e.g. tools/verify.py
    # --devices N): every dist method on the main dtypes.
    scenarios += _grid(
        ("dist",), ("int32", "uint32", "float32"), ("random", "dupes", "sorted"),
        (1024, 3072), (1,),
    )
    # x64=False pins the cell set: the committed smoke baseline must not
    # grow int64 jit cells when someone runs with JAX_ENABLE_X64=1.
    return [
        sc
        for sc in scenarios
        if prune_reason(sc, devices=devices, mesh_axes=mesh_axes, x64=False) is None
    ]


def tier1_grid() -> list[Scenario]:
    """The fast pytest subset — a strict subset of :func:`smoke_grid` (so
    the committed smoke baseline covers it) touching every dtype, every
    distribution, both single-box paths, and one higher-dimension cell."""
    smoke = {sc.scenario_id: sc for sc in smoke_grid(devices=1)}
    picked: list[Scenario] = []
    for dtype, dist in zip(
        ("int8", "int16", "int32", "int64", "uint32", "float32", "int32", "int32"),
        ("random", "dupes", "local", "sorted", "reversed", "random", "dupes", "sorted"),
    ):
        for path in ("sim", "host"):
            sc = Scenario(path, "paper", dtype, dist, 257, 1)
            if sc.scenario_id in smoke:
                picked.append(smoke[sc.scenario_id])
    # sampled-method and dimension coverage
    for sc in (
        Scenario("sim", "sampled", "uint32", "local", 257, 1),
        Scenario("sim", "sampled", "int8", "random", 257, 1),
        Scenario("host", "sampled", "int64", "random", 257, 1),
        Scenario("sim", "paper", "int32", "random", 64, 2),
    ):
        if sc.scenario_id in smoke:
            picked.append(smoke[sc.scenario_id])
    # dedupe, preserve order
    seen: set[str] = set()
    out = []
    for sc in picked:
        if sc.scenario_id not in seen:
            seen.add(sc.scenario_id)
            out.append(sc)
    return out


# ---------------------------------------------------------------- segments
# The segmented-batch twin of the grid above: one SegmentScenario is one
# forced (row-sort method × dtype × row class × length mix) cell of the
# ``sort_segments`` hot path, covering every row backend the engine's
# autotune can pick (vmapped XLA and both fused Pallas variants) so the
# drift baseline owns the batched kernel too (DESIGN.md §7, §8).

SEGMENT_METHODS = ("bitonic", "bitonic_pallas", "bitonic2op")

# Row classes: uniform keys, dtype-max sentinel-tie mixes (the pad-collision
# class the tagged kernels exist for), all-equal rows, reversed ramps.
SEGMENT_ROW_CLASSES = ("random", "ties", "equal", "ramp")

# Longest-row values straddling pow2 shape buckets (128 and 1024).
SEGMENT_MAX_LENS = (100, 1000)

SEGMENT_DTYPES = ("int32", "uint32", "float32")


@dataclasses.dataclass(frozen=True)
class SegmentScenario:
    """One executable cell of the segmented-batch conformance grid."""

    method: str  # forced row-sort method (SEGMENT_METHODS)
    dtype: str
    rows: str  # row class (SEGMENT_ROW_CLASSES)
    max_len: int  # longest row; the pow2 bucket comes from bucketed_length
    seed: int = 7

    # the single-array grid's duck-typed surface (baseline + cross-check)
    path = "sim"

    @property
    def scenario_id(self) -> str:
        return f"seg/{self.method}/{self.dtype}/{self.rows}/L{self.max_len}"

    @property
    def group_id(self) -> str:
        """Cells sharing it sort the same batch: every method must agree."""
        return f"seg/{self.dtype}/{self.rows}/L{self.max_len}/s{self.seed}"

    def make_batch(self) -> "tuple[np.ndarray, list[int]]":
        """The flat keys + segment lengths for this cell (deterministic).

        Lengths include the degenerate rows (0, 1) plus draws up to
        ``max_len`` so the batch straddles intra-bucket variation.
        """
        rng = np.random.default_rng(self.seed + self.max_len)
        lens = [0, 1, self.max_len] + [
            int(v) for v in rng.integers(2, self.max_len + 1, 4)
        ]
        dt = np.dtype(self.dtype)
        segs = []
        for n in lens:
            if self.rows == "random":
                if np.issubdtype(dt, np.integer):
                    info = np.iinfo(dt)
                    segs.append(rng.integers(info.min, info.max, n, dtype=dt))
                else:
                    segs.append(rng.normal(size=n).astype(dt))
            elif self.rows == "ties":
                hi = np.iinfo(dt).max
                segs.append(np.where(rng.random(n) < 0.5, hi, hi - 1).astype(dt))
            elif self.rows == "equal":
                segs.append(np.full(n, 42, dt))
            elif self.rows == "ramp":
                segs.append(np.arange(n, 0, -1).astype(dt))
            else:
                raise ValueError(f"unknown row class {self.rows!r}")
        flat = np.concatenate(segs) if segs else np.zeros(0, dt)
        return flat, lens


def segment_prune_reason(sc: SegmentScenario) -> "str | None":
    if sc.method not in SEGMENT_METHODS:
        return f"unknown segment method {sc.method!r}"
    if sc.rows not in SEGMENT_ROW_CLASSES:
        return f"unknown row class {sc.rows!r}"
    if sc.rows == "ties" and not np.issubdtype(np.dtype(sc.dtype), np.integer):
        return "sentinel-tie rows are an integer-key class (float pad is +inf)"
    return None


def segment_smoke_grid() -> "list[SegmentScenario]":
    """Every runnable segment cell: method × dtype × row class × length."""
    out = []
    for method, dtype, rows, max_len in itertools.product(
        SEGMENT_METHODS, SEGMENT_DTYPES, SEGMENT_ROW_CLASSES, SEGMENT_MAX_LENS
    ):
        sc = SegmentScenario(method, dtype, rows, max_len)
        if segment_prune_reason(sc) is None:
            out.append(sc)
    return out


def segment_tier1_grid() -> "list[SegmentScenario]":
    """Fast pytest subset: every method and row class at one size each."""
    picked = [
        SegmentScenario("bitonic", "int32", "random", 100),
        SegmentScenario("bitonic_pallas", "int32", "ties", 100),
        SegmentScenario("bitonic_pallas", "uint32", "random", 1000),
        SegmentScenario("bitonic2op", "int32", "equal", 1000),
        SegmentScenario("bitonic2op", "uint32", "ties", 100),
        SegmentScenario("bitonic_pallas", "float32", "ramp", 1000),
    ]
    smoke_ids = {sc.scenario_id for sc in segment_smoke_grid()}
    return [sc for sc in picked if sc.scenario_id in smoke_ids]


# ------------------------------------------------------------------ faults
# The degraded-topology slice of the grid (DESIGN.md §11): one FaultCell is
# one (fault class × topology × path) cell run with the engine's
# ``fault_scenario`` set.  Cells sharing a topology sort the *same* input,
# so the cross-check asserts the degraded runs (and the typed host
# fallbacks of impossible scenarios) stay byte-identical to the healthy
# run — the "zero wrong answers under faults" pin.

# healthy  — scenario None, the byte-reference the others must match;
# optical  — group 1's OTIS uplink dead (reroutable: relay chains);
# klinks2  — 2 seeded-random dead links (reroutable on every grid topo);
# uplinks  — every OTIS uplink of group 1 dead (GatherImpossible: the
#            group is optically islanded → typed host fallback);
# worker   — group 1's hub node dead (GatherImpossible: internal
#            destination → typed host fallback; the fleet's kill twin).
FAULT_CLASSES = ("healthy", "optical", "klinks2", "uplinks", "worker")

# Fault classes whose gather is impossible: forced sim plans must come back
# rewritten to the host path (the fallback ladder's bottom rung).
FAULT_IMPOSSIBLE = ("uplinks", "worker")

FAULT_TOPOLOGIES = ((1, "full"), (2, "full"), (1, "half"))

FAULT_PATHS = ("sim", "host")


@dataclasses.dataclass(frozen=True)
class FaultCell:
    """One executable cell of the degraded-topology conformance grid."""

    fault: str  # FAULT_CLASSES
    d_h: int
    variant: str
    path: str  # requested path; the *executed* path lands in the baseline
    n: int = 2048
    seed: int = 11

    # the single-array grid's duck-typed surface (forced_plan + baselines)
    method = "paper"

    @property
    def scenario_id(self) -> str:
        var = "" if self.variant == "full" else f"-{self.variant}"
        return f"fault/{self.fault}/d{self.d_h}{var}/{self.path}"

    @property
    def group_id(self) -> str:
        """Same topology ⇒ same input: every fault class and path in the
        group must agree byte-for-byte with the healthy cell."""
        var = "" if self.variant == "full" else f"-{self.variant}"
        return f"fault/d{self.d_h}{var}/n{self.n}/s{self.seed}"

    def make_input(self) -> np.ndarray:
        from repro.data.distributions import make_array

        return make_array("random", self.n, seed=self.seed, dtype=np.dtype("int32"))

    def scenario(self, topo):
        """The cell's FaultScenario on ``topo`` (None for the healthy ref)."""
        from repro.net.faults import FaultScenario

        if self.fault == "healthy":
            return None
        if self.fault == "optical":
            return FaultScenario.optical_link_down(1)
        if self.fault == "klinks2":
            return FaultScenario.random_links(topo, 2, seed=3)
        if self.fault == "uplinks":
            return FaultScenario.group_uplinks_down(topo, 1)
        if self.fault == "worker":
            return FaultScenario.worker_down(1)
        raise ValueError(f"unknown fault class {self.fault!r}")


def fault_grid() -> "list[FaultCell]":
    """Every degraded-grid cell: fault class × topology × path (no pruning
    — every class is constructible on every grid topology, and impossible
    scenarios are *cells that must fall back*, not cells to skip)."""
    return [
        FaultCell(fault, d_h, variant, path)
        for fault in FAULT_CLASSES
        for d_h, variant in FAULT_TOPOLOGIES
        for path in FAULT_PATHS
    ]


# --------------------------------------------------------------- workloads
# The operation axis of the grid (DESIGN.md §12): the paper varies
# dimension/dtype/distribution/size for ONE op (full sort); these cells
# vary the op itself.  Each op has its own oracle (run_op_scenario), and
# ops producing the full sorted array share a byte-compare group with the
# plain sort cell of the same input.

WORKLOAD_OPS = ("sort", "top_k", "pairs_pytree", "merge")

OP_DTYPES = ("int32", "uint32", "float32")
OP_DISTS = ("random", "dupes", "local")
OP_SIZES = (257, 2048)
# top_k runs at two head fractions: k = n//8 lands in the host skip regime
# (most buckets past the cut), k = n//2 keeps the sim partial-sort path
# live — both dispatch arms stay pinned.
OP_K_DIVS = (8, 2)


@dataclasses.dataclass(frozen=True)
class OpScenario:
    """One executable cell of the workload conformance grid."""

    op: str  # WORKLOAD_OPS
    dtype: str
    dist: str
    n: int
    k_div: int = 0  # top_k only: k = max(1, n // k_div)
    seed: int = 7

    # the single-array grid's duck-typed surface; the *executed* path and
    # method land in the baseline from the engine report
    path = "sim"
    method = "op"

    @property
    def k(self) -> int:
        return max(1, self.n // self.k_div) if self.k_div else 0

    @property
    def scenario_id(self) -> str:
        kk = f"/k{self.k}" if self.op == "top_k" else ""
        return f"op/{self.op}/{self.dtype}/{self.dist}/n{self.n}{kk}"

    @property
    def group_id(self) -> str:
        """sort, pairs_pytree, and merge all produce the full sorted array
        of the same input → one byte-compare group; top_k heads group per
        ``k`` (every op computing the same head must agree)."""
        head = f"head{self.k}" if self.op == "top_k" else "full"
        return f"op/{head}/{self.dtype}/{self.dist}/n{self.n}/s{self.seed}"

    def make_input(self) -> np.ndarray:
        from repro.data.distributions import make_array

        return make_array(
            self.dist, self.n, seed=self.seed, dtype=np.dtype(self.dtype)
        )


def op_prune_reason(sc: OpScenario) -> "str | None":
    if sc.op not in WORKLOAD_OPS:
        return f"unknown op {sc.op!r}"
    if sc.op == "top_k" and sc.k_div == 0:
        return "top_k cells need a k divisor"
    if sc.op != "top_k" and sc.k_div != 0:
        return f"{sc.op} cells take no k divisor"
    if np.dtype(sc.dtype).itemsize == 8:
        return "64-bit keys ride the single-array grid's host rows"
    return None


def op_smoke_grid() -> "list[OpScenario]":
    """Every runnable op cell: op × dtype × distribution × size (+ k)."""
    out = []
    for dtype, dist, n in itertools.product(OP_DTYPES, OP_DISTS, OP_SIZES):
        for op in WORKLOAD_OPS:
            if op == "top_k":
                out.extend(
                    OpScenario(op, dtype, dist, n, k_div) for k_div in OP_K_DIVS
                )
            else:
                out.append(OpScenario(op, dtype, dist, n))
    return [sc for sc in out if op_prune_reason(sc) is None]


def op_tier1_grid() -> "list[OpScenario]":
    """Fast pytest subset: every op, both top_k regimes, mixed dtypes."""
    picked = [
        OpScenario("sort", "int32", "random", 257),
        OpScenario("top_k", "int32", "random", 257, 8),
        OpScenario("top_k", "int32", "dupes", 2048, 2),
        OpScenario("top_k", "uint32", "local", 2048, 8),
        OpScenario("pairs_pytree", "int32", "random", 257),
        OpScenario("pairs_pytree", "float32", "dupes", 2048),
        OpScenario("merge", "int32", "random", 2048),
        OpScenario("merge", "uint32", "dupes", 257),
    ]
    smoke_ids = {sc.scenario_id for sc in op_smoke_grid()}
    return [sc for sc in picked if sc.scenario_id in smoke_ids]


def pruned_cells(
    scenarios: "Sequence[Scenario] | None" = None,
    *,
    devices: int = 1,
    mesh_axes: int = 1,
) -> list[tuple[Scenario, str]]:
    """The audit list: every (scenario, reason) the environment prunes."""
    if scenarios is None:
        scenarios = list(_grid(PATHS, DTYPES, ALL_DISTRIBUTIONS, SIZE_BUCKETS, DIMS))
    out = []
    for sc in scenarios:
        reason = prune_reason(sc, devices=devices, mesh_axes=mesh_axes)
        if reason is not None:
            out.append((sc, reason))
    return out
