"""Per-scenario baselines with drift detection (DESIGN.md §7).

A baseline is a JSON document mapping ``scenario_id`` → the *stable*
outcome of that cell: pass/fail status, the executed path/method, the
autotuned capacity, and the overflow-retry count.  Timings are explicitly
excluded — a baseline diff must be empty across machines.

Drift policy: any change — a scenario appearing, disappearing, or any
recorded field flipping (e.g. the capacity model now picks a different
buffer, or a plan policy change reroutes a cell) — fails the conformance
run until someone re-records the baseline with ``tools/verify.py
--update-baseline``.  Plan-policy changes therefore always show up in
review as a baseline-file diff, never as a silent behavioural flip.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Sequence

SCHEMA_VERSION = 1

# The stable per-scenario fields, in persisted order.
RECORD_FIELDS = ("status", "path", "method", "capacity", "retries")


def result_record(result) -> dict:
    """The baseline-stable projection of a ScenarioResult."""
    return {
        "status": result.status,
        "path": result.path,
        "method": result.method,
        "capacity": result.capacity if result.capacity is None else int(result.capacity),
        "retries": int(result.retries),
    }


def build_baseline(results: Sequence, *, grid: str) -> dict:
    """Results → baseline document (deterministically ordered)."""
    scenarios = {r.scenario_id: result_record(r) for r in results}
    return {
        "schema": SCHEMA_VERSION,
        "grid": grid,
        "scenario_count": len(scenarios),
        "scenarios": {k: scenarios[k] for k in sorted(scenarios)},
    }


def save_baseline(doc: dict, path) -> None:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")


def load_baseline(path) -> dict:
    doc = json.loads(pathlib.Path(path).read_text())
    if doc.get("schema") != SCHEMA_VERSION:
        raise ValueError(
            f"baseline schema {doc.get('schema')!r} != supported {SCHEMA_VERSION}"
        )
    return doc


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """Differences between a fresh run and the committed baseline."""

    added: tuple  # scenario_ids present now, absent in baseline
    removed: tuple  # scenario_ids in baseline, absent now
    changed: tuple  # (scenario_id, field, baseline_value, current_value)

    @property
    def clean(self) -> bool:
        return not (self.added or self.removed or self.changed)

    def summary(self) -> str:
        if self.clean:
            return "no drift"
        lines = []
        for sid in self.added:
            lines.append(f"ADDED    {sid}")
        for sid in self.removed:
            lines.append(f"REMOVED  {sid}")
        for sid, field, old, new in self.changed:
            lines.append(f"CHANGED  {sid}: {field} {old!r} -> {new!r}")
        lines.append(
            f"drift: {len(self.added)} added, {len(self.removed)} removed, "
            f"{len(self.changed)} changed"
        )
        return "\n".join(lines)


def diff_baselines(
    current: dict, baseline: dict, *, ignore_missing_in_current: bool = False
) -> DriftReport:
    """Compare a fresh document against the committed baseline.

    ``ignore_missing_in_current=True`` supports subset runs (the tier-1
    pytest slice re-checks only its own cells against the full committed
    smoke baseline).
    """
    cur = current.get("scenarios", {})
    base = baseline.get("scenarios", {})
    added = tuple(sorted(k for k in cur if k not in base))
    removed = (
        ()
        if ignore_missing_in_current
        else tuple(sorted(k for k in base if k not in cur))
    )
    changed = []
    for sid in sorted(set(cur) & set(base)):
        for field in RECORD_FIELDS:
            old, new = base[sid].get(field), cur[sid].get(field)
            if old != new:
                changed.append((sid, field, old, new))
    return DriftReport(added=added, removed=removed, changed=tuple(changed))
