"""Metamorphic properties and fault-scenario stress (DESIGN.md §7).

Oracle-free checks that hold for *any* correct sort, so they catch bug
classes a single ``np.sort`` comparison can miss (e.g. an executor that
"fixes up" its output by re-sorting a corrupted buffer would still pass
the oracle — but not duplicate-mass preservation against the original
input it was handed):

* **ordering**      — output is non-decreasing;
* **permutation**   — output is a permutation of the input (multiset
  equality via value/count tables — also duplicate-mass preservation);
* **shuffle invariance** — sorting any permutation of the input yields the
  identical array;
* **idempotence**   — sorting the output changes nothing;
* **pairing**       — ``sort_pairs`` keeps every (key, value) pair intact:
  the value column is the permutation that sorts the key column.

Fault stress: the paper's gather must survive degraded networks.  We take
the *actual per-processor bucket loads of an engine run* (the plan's chunk
sizes), rebuild the accumulation schedule for each
:class:`repro.net.faults.FaultScenario`, and replay it through the
event-driven simulator — asserting complete delivery, zero simulator-level
reroutes (the rebuilt schedule must be self-sufficient), and a makespan no
better than the healthy network's.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import SortEngine
from repro.core.schedule import AccumulationSchedule
from repro.core.topology import OHHCTopology


@dataclasses.dataclass(frozen=True)
class CheckResult:
    check: str
    subject: str
    status: str  # 'pass' | 'fail'
    detail: str = ""


def _multiset_equal(a: np.ndarray, b: np.ndarray) -> bool:
    va, ca = np.unique(a, return_counts=True)
    vb, cb = np.unique(b, return_counts=True)
    return va.shape == vb.shape and bool(np.all(va == vb) and np.all(ca == cb))


def metamorphic_checks(
    eng: SortEngine, x: np.ndarray, *, subject: str = "", seed: int = 0
) -> list[CheckResult]:
    """Run the full metamorphic battery on one input through ``eng``."""
    x = np.asarray(x).ravel()
    out = np.asarray(eng.sort(x))
    results = []

    def add(check: str, ok: bool, detail: str = ""):
        results.append(CheckResult(check, subject, "pass" if ok else "fail", detail))

    add("ordering", bool(np.all(out[:-1] <= out[1:])), "output not non-decreasing")
    add(
        "permutation",
        _multiset_equal(x, out),
        "output is not a permutation of the input (duplicate mass changed)",
    )
    rng = np.random.default_rng(seed)
    shuffled = x.copy()
    rng.shuffle(shuffled)
    add(
        "shuffle-invariance",
        bool(np.array_equal(np.asarray(eng.sort(shuffled)), out)),
        "sorting a shuffled copy produced a different array",
    )
    add(
        "idempotence",
        bool(np.array_equal(np.asarray(eng.sort(out)), out)),
        "sorting the sorted output changed it",
    )
    return results


def pairs_pairing_check(
    eng: SortEngine, keys: np.ndarray, vals: np.ndarray, *, subject: str = ""
) -> list[CheckResult]:
    """``sort_pairs`` contract: keys come back sorted and the value column
    is a permutation that reproduces exactly the input (key, value) pairs."""
    keys = np.asarray(keys).ravel()
    vals = np.asarray(vals).ravel()
    ks, vs = eng.sort_pairs(keys, vals)
    ks, vs = np.asarray(ks), np.asarray(vs)
    results = []

    def add(check: str, ok: bool, detail: str = ""):
        results.append(CheckResult(check, subject, "pass" if ok else "fail", detail))

    add("pairs-ordering", bool(np.all(ks[:-1] <= ks[1:])), "keys not sorted")
    got = sorted(zip(ks.tolist(), vs.tolist()))
    want = sorted(zip(keys.tolist(), vals.tolist()))
    add("pairs-pairing", got == want, "(key, value) pairs were not preserved")
    return results


def fault_replay(
    topo: OHHCTopology,
    chunk_sizes: Sequence[int],
    *,
    groups: "Sequence[int] | None" = None,
    itemsize: int = 4,
) -> list[CheckResult]:
    """Replay the gather under optical-link faults with the plan's loads.

    ``chunk_sizes`` is the per-processor bucket load of an engine run (the
    ``counts`` field of ``SortEngine.last_report``) — the dist plan's
    actual traffic, not a uniform idealisation.  For each faulted group the
    degraded schedule must deliver every element to the master with no
    simulator-level rerouting, and cannot beat the healthy makespan.
    """
    from repro.net.faults import (
        FaultScenario,
        GatherImpossible,
        degraded_gather_rounds,
    )
    from repro.net.links import LinkModel
    from repro.net.sim import simulate_schedule

    sizes = list(int(c) for c in chunk_sizes)
    if len(sizes) != topo.total_procs:
        raise ValueError(
            f"chunk_sizes has {len(sizes)} entries for {topo.total_procs} procs"
        )
    total = sum(sizes)
    lm = LinkModel()
    healthy = simulate_schedule(
        AccumulationSchedule.build(topo), topo,
        link_model=lm, chunk_sizes=sizes, itemsize=itemsize,
    )
    results = [
        CheckResult(
            "fault-healthy-delivery",
            "healthy",
            "pass" if healthy.master_elems == total else "fail",
            f"master got {healthy.master_elems}/{total}",
        )
    ]
    if groups is None:
        groups = (1, topo.num_groups - 1) if topo.num_groups > 2 else (1,)
    for g in groups:
        scenario = FaultScenario.optical_link_down(g)
        subject = scenario.name
        try:
            rounds = degraded_gather_rounds(topo, scenario)
            res = simulate_schedule(
                rounds, topo,
                link_model=lm, router=scenario.router(topo),
                chunk_sizes=sizes, itemsize=itemsize,
            )
        except GatherImpossible as e:
            results.append(CheckResult("fault-delivery", subject, "fail", str(e)))
            continue
        ok = res.master_elems == total
        results.append(
            CheckResult(
                "fault-delivery", subject, "pass" if ok else "fail",
                f"master got {res.master_elems}/{total}",
            )
        )
        results.append(
            CheckResult(
                "fault-no-sim-reroute", subject,
                "pass" if res.rerouted_messages == 0 else "fail",
                f"{res.rerouted_messages} sends still needed simulator reroutes",
            )
        )
        results.append(
            CheckResult(
                "fault-makespan-sane", subject,
                "pass" if res.total_time_s >= healthy.total_time_s - 1e-12 else "fail",
                f"degraded {res.total_time_s:.3e}s < healthy {healthy.total_time_s:.3e}s",
            )
        )
    return results


def fault_replay_for_engine_run(
    eng: SortEngine, x: np.ndarray, **kw
) -> list[CheckResult]:
    """Sort ``x``, then replay faults with that run's measured bucket loads."""
    eng.sort(x)
    report = eng.last_report or {}
    counts = report.get("counts")
    if counts is None:
        raise ValueError("engine report carries no per-bucket counts for this path")
    return fault_replay(eng.topo, np.asarray(counts), itemsize=x.dtype.itemsize, **kw)
