"""Per-link-class timing parameters for the OHHC link simulator.

The paper's conclusion laments that "the difference in the speed of the
electrical and optical connections ... was not taken into consideration";
``repro.core.ohhc_sort.LinkModel`` models it analytically (one
bandwidth/latency pair per class, used by the closed-form round model).
This module is the *simulator-grade* version (DESIGN.md §6): each link
class carries the full LogP-style triple

* ``startup_us``  — per-message software/SerDes overhead paid at the sender
  before the first byte moves (the classic ``t_s``),
* ``latency_us``  — wire propagation delay (``t_l``), paid once per hop,
* ``gbps``        — link bandwidth in GB/s (``1/t_b`` per byte).

so a hop carrying ``m`` bytes costs ``startup + latency + m/bw`` and a
store-and-forward route of ``h`` hops costs the sum over its hops — the
Theorem-6 ``t·(2·d_h+3)`` structure with the constants made explicit.
"""

from __future__ import annotations

import dataclasses

from repro.core.ohhc_sort import LinkModel as CoreLinkModel

ELECTRICAL = "electrical"
OPTICAL = "optical"


@dataclasses.dataclass(frozen=True)
class LinkClass:
    """Timing of one link class (electrical or optical)."""

    startup_us: float
    latency_us: float
    gbps: float  # GB/s; float('inf') disables the bandwidth term

    def hop_time_s(self, nbytes: float) -> float:
        t = (self.startup_us + self.latency_us) * 1e-6
        if self.gbps != float("inf"):
            t += nbytes / (self.gbps * 1e9)
        return t


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Electronic vs optical link asymmetry (paper §1.3).

    Defaults mirror ``repro.core.ohhc_sort.LinkModel`` (≈ TPU v5e ICI vs
    inter-pod numbers) so simulated and analytic times are directly
    comparable: same 1 µs per-message overhead, 50 vs 25 GB/s.
    """

    electrical: LinkClass = LinkClass(startup_us=1.0, latency_us=0.0, gbps=50.0)
    optical: LinkClass = LinkClass(startup_us=1.0, latency_us=0.0, gbps=25.0)

    def link_class(self, kind: str) -> LinkClass:
        if kind == ELECTRICAL:
            return self.electrical
        if kind == OPTICAL:
            return self.optical
        raise ValueError(f"unknown link kind {kind!r}")

    def hop_time_s(self, kind: str, nbytes: float) -> float:
        return self.link_class(kind).hop_time_s(nbytes)

    # ---- constructors -------------------------------------------------------
    @classmethod
    def unit(cls, step_us: float = 1.0) -> "LinkModel":
        """Byte-agnostic model: every hop costs exactly ``step_us``.

        Under this model the simulated gather time divided by ``step_us``
        *is* the critical-path hop count, which is how the simulator
        validates Theorem 3 / Theorem 6 round accounting against a
        measured timeline rather than a formula.
        """
        u = LinkClass(startup_us=step_us, latency_us=0.0, gbps=float("inf"))
        return cls(electrical=u, optical=u)

    @classmethod
    def from_core(cls, core: CoreLinkModel) -> "LinkModel":
        """Bridge from the analytic cost model's parameters."""
        return cls(
            electrical=LinkClass(core.alpha_us, 0.0, core.electrical_gbps),
            optical=LinkClass(core.alpha_us, 0.0, core.optical_gbps),
        )

    def to_core(self) -> CoreLinkModel:
        """Project onto the analytic model (drops the latency split)."""
        return CoreLinkModel(
            electrical_gbps=self.electrical.gbps,
            optical_gbps=self.optical.gbps,
            alpha_us=self.electrical.startup_us + self.electrical.latency_us,
        )
