"""repro.net — event-driven OHHC link simulator (DESIGN.md §6).

Moves the schedule's messages over the actual electrical/optical link
graph: BFS routing, per-link occupancy and contention, fault injection
with reroute-or-fail semantics, and trace-validated Theorem-3/6 round
accounting.
"""

from repro.net.faults import FaultScenario, GatherImpossible, rebuild_degraded
from repro.net.links import ELECTRICAL, OPTICAL, LinkClass, LinkModel
from repro.net.report import case_report, netsim_report, to_markdown, write_json
from repro.net.router import RouteError, Router
from repro.net.sim import (
    MessageTrace,
    PhaseSpan,
    SimResult,
    critical_hop_count,
    simulate_gather,
    simulate_schedule,
)

__all__ = [
    "ELECTRICAL",
    "OPTICAL",
    "FaultScenario",
    "GatherImpossible",
    "LinkClass",
    "LinkModel",
    "MessageTrace",
    "PhaseSpan",
    "RouteError",
    "Router",
    "SimResult",
    "case_report",
    "critical_hop_count",
    "netsim_report",
    "rebuild_degraded",
    "simulate_gather",
    "simulate_schedule",
    "to_markdown",
    "write_json",
]
