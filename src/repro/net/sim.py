"""Event-driven execution of a gather schedule over the OHHC link graph.

This is the measured-timeline counterpart of the analytic models in
``repro.core.ohhc_sort`` (DESIGN.md §6).  The input is any list of rounds
of :class:`repro.core.schedule.Send` — ``AccumulationSchedule.rounds``
plugs in unchanged, as do the degraded schedules from ``repro.net.faults``
— and the output is a :class:`SimResult` timeline with per-phase spans,
per-link-class utilization, and contention counters.

Semantics (deliberately *not* a per-round barrier):

* a message becomes ready when its **source node** has received every
  earlier-round message addressed to it (the paper's static
  WaitForSubArrays discipline — a node forwards once its wait count is
  met; messages to *other* nodes never gate it);
* each message carries the chunks its source has accumulated so far
  (element counts tracked exactly as ``simulate_chunk_counts``), and is
  **store-and-forward**: a route of h hops pays the full per-hop cost h
  times;
* each undirected link serves **one message at a time per direction**;
  a busy link queues the message and the wait is counted as contention
  (zero on the healthy schedule, whose rounds use disjoint links —
  nonzero exactly when faults force reroutes onto shared links).

Under ``LinkModel.unit()`` every hop costs one time unit, so
``total_time_s / unit`` equals the schedule's critical-path hop count —
the measured-timeline validation of Theorem 3 / Theorem 6 accounting that
``tests/test_netsim.py`` pins for every (d_h, variant).  ``barrier=True``
switches to the paper's BSP accounting (no round starts before the
previous round fully drains); the dependency default exposes a
reproduction finding: the **half** variant finishes in ``2·d_h + 2``
rounds, one under the paper's ``2·d_h + 3``, because its optical-hole
nodes (``local ≥ G``) receive no optical payload and forward early.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence

from repro.core.schedule import AccumulationSchedule, Send
from repro.core.topology import OHHCTopology

from repro.net.links import ELECTRICAL, OPTICAL, LinkModel
from repro.net.router import RouteError, Router, canonical_link

_EPS = 1e-15


@dataclasses.dataclass(frozen=True)
class MessageTrace:
    """One delivered point-to-point message (possibly multi-hop)."""

    send: Send
    elems: int  # elements carried (accumulated chunks)
    nbytes: int
    start_s: float  # source became ready to transmit
    end_s: float  # last hop arrived at the destination
    hops: int
    wait_s: float  # total time spent queued on busy links
    rerouted: bool  # direct link dead → BFS alternative used


@dataclasses.dataclass(frozen=True)
class PhaseSpan:
    phase: str
    start_s: float
    end_s: float
    sends: int
    hops: int
    electrical_bytes: int
    optical_bytes: int
    contention_events: int


@dataclasses.dataclass(frozen=True)
class SimResult:
    total_time_s: float
    messages: int
    hops: int
    rerouted_messages: int
    contention_events: int
    contention_wait_s: float
    link_busy_s: dict  # kind -> summed busy seconds
    link_utilization: dict  # kind -> busy / (live links × makespan)
    max_link_busy_s: float  # hottest single directed link
    phases: tuple  # PhaseSpan, in execution order
    master_elems: int  # elements accumulated at the gather root
    traces: tuple  # MessageTrace, schedule (round, send) order

    def phase_by_name(self) -> dict:
        return {p.phase: p for p in self.phases}


def _as_rounds(schedule) -> Sequence[Sequence[Send]]:
    if isinstance(schedule, AccumulationSchedule):
        return schedule.rounds
    return schedule


def simulate_schedule(
    schedule,
    topo: OHHCTopology,
    *,
    link_model: LinkModel | None = None,
    router: Router | None = None,
    chunk_sizes: "Sequence[int] | int" = 1,
    itemsize: int = 4,
    master: tuple[int, int] = (0, 0),
    barrier: bool = False,
) -> SimResult:
    """Run ``schedule`` (rounds of ``Send``) and return the timeline.

    ``chunk_sizes`` is elements per processor (scalar = uniform), matching
    ``payload_bytes_per_round``; ``router`` carries fault state (default:
    healthy graph).  ``barrier=True`` uses per-round BSP barriers (the
    paper's accounting) instead of per-node wait-count dependencies.
    Raises :class:`RouteError` when a send's endpoints are disconnected —
    the "fail" half of reroute-or-fail.
    """
    link_model = link_model if link_model is not None else LinkModel()
    router = router if router is not None else Router(topo)
    rounds = _as_rounds(schedule)

    if isinstance(chunk_sizes, int):
        sizes = [chunk_sizes] * topo.total_procs
    else:
        sizes = list(chunk_sizes)
        if len(sizes) != topo.total_procs:
            raise ValueError(
                f"chunk_sizes has {len(sizes)} entries for {topo.total_procs} procs"
            )

    held = {gid: sizes[gid] for gid in range(topo.total_procs)}
    node_ready = {gid: 0.0 for gid in range(topo.total_procs)}
    link_free: dict[tuple[int, int, int], float] = {}  # (a, b, dir) -> time
    link_busy = {ELECTRICAL: 0.0, OPTICAL: 0.0}
    per_link_busy: dict[tuple[int, int, int], float] = {}

    traces: list[MessageTrace] = []
    phase_acc: dict[str, dict] = {}
    phase_order: list[str] = []
    contention_events = 0
    contention_wait = 0.0
    total_hops = 0
    rerouted_count = 0
    t_barrier = 0.0

    for rnd in rounds:
        # Stage payloads first: all sends in a round observe the counts
        # from previous rounds (same convention as simulate_chunk_counts).
        # Draining at read time keeps element conservation even for
        # schedules where one source appears twice in a round (possible in
        # rebuilt degraded schedules): the second send carries 0, never a
        # double-counted copy.
        staged = []
        for s in rnd:
            src = topo.global_id(*s.src)
            dst = topo.global_id(*s.dst)
            elems = held[src]
            held[src] = 0
            staged.append((s, src, dst, elems))

        # Event loop, chronological: each message advances hop by hop; a
        # hop that finds its link busy re-requests at the link's free time,
        # so links are granted first-come-first-served *in simulated time*
        # (never by processing order — a reservation can't block a message
        # that was ready while the link sat idle).  Ties break by first
        # request time, then message index, so runs are deterministic.
        msgs = []
        heap: list[tuple[float, float, int]] = []  # (event t, request t, idx)
        for i, (s, src, dst, elems) in enumerate(staged):
            start = max(node_ready[src], t_barrier) if barrier else node_ready[src]
            direct = router.link_kind(src, dst)
            if src == dst:
                hops, rerouted = [], False  # self-send: delivered in place
            elif direct is not None:
                hops = [(src, dst, direct)]
                rerouted = False
            else:
                hops = router.shortest_path(src, dst)  # raises RouteError
                rerouted = True
                rerouted_count += 1
            msgs.append(
                {
                    "s": s, "src": src, "dst": dst, "elems": elems,
                    "start": start, "hops": hops, "hop_i": 0, "t": start,
                    "wait": 0.0, "req": None, "rerouted": rerouted,
                }
            )
            heapq.heappush(heap, (start, start, i))
        arrivals = []  # (dst, arrival) applied after the round drains
        while heap:
            now, _, i = heapq.heappop(heap)
            m = msgs[i]
            if m["hop_i"] >= len(m["hops"]):  # zero-hop (src == dst)
                arrivals.append((m["dst"], m["t"]))
                continue
            u, v, kind = m["hops"][m["hop_i"]]
            a, b = canonical_link(u, v)
            key = (a, b, 0 if u == a else 1)
            free = link_free.get(key, 0.0)
            if free > now + _EPS:
                if m["req"] is None:
                    m["req"] = now  # first time this hop found the link busy
                heapq.heappush(heap, (free, m["req"], i))
                continue
            if m["req"] is not None:
                contention_events += 1
                m["wait"] += now - m["req"]
                m["req"] = None
            hop_t = link_model.hop_time_s(kind, m["elems"] * itemsize)
            m["t"] = now + hop_t
            link_free[key] = m["t"]
            link_busy[kind] += hop_t
            per_link_busy[key] = per_link_busy.get(key, 0.0) + hop_t
            m["hop_i"] += 1
            if m["hop_i"] < len(m["hops"]):
                heapq.heappush(heap, (m["t"], m["t"], i))
            else:
                arrivals.append((m["dst"], m["t"]))
        for m in msgs:
            s, elems, hops = m["s"], m["elems"], m["hops"]
            nbytes = elems * itemsize
            # Credit the payload to where the route actually *ends*, not
            # the schedule's declared destination — so master_elems
            # measures delivery (a routing bug misdelivers and the counts
            # drop) rather than restating the schedule's bookkeeping.
            landed = hops[-1][1] if hops else m["dst"]
            held[landed] += elems
            contention_wait += m["wait"]
            total_hops += len(hops)
            traces.append(
                MessageTrace(
                    send=s,
                    elems=elems,
                    nbytes=nbytes,
                    start_s=m["start"],
                    end_s=m["t"],
                    hops=len(hops),
                    wait_s=m["wait"],
                    rerouted=m["rerouted"],
                )
            )
            acc = phase_acc.setdefault(
                s.phase,
                {
                    "start": m["start"],
                    "end": m["t"],
                    "sends": 0,
                    "hops": 0,
                    "e_bytes": 0,
                    "o_bytes": 0,
                    "contention": 0,
                },
            )
            if s.phase not in phase_order:
                phase_order.append(s.phase)
            acc["start"] = min(acc["start"], m["start"])
            acc["end"] = max(acc["end"], m["t"])
            acc["sends"] += 1
            acc["hops"] += len(hops)
            for u, v, kind in hops:
                acc["e_bytes" if kind == ELECTRICAL else "o_bytes"] += nbytes
            if m["wait"] > _EPS:
                acc["contention"] += 1
        # A node may forward in a later round only after everything routed
        # to it in this round has landed.
        for dst, t in arrivals:
            node_ready[dst] = max(node_ready[dst], t)
        if barrier and arrivals:
            t_barrier = max(t_barrier, max(t for _, t in arrivals))

    makespan = max((tr.end_s for tr in traces), default=0.0)
    links_of_kind = {ELECTRICAL: 0, OPTICAL: 0}
    for kind in router.live_links().values():
        links_of_kind[kind] += 1
    utilization = {
        # busy link-seconds / available directed link-seconds of that class
        kind: (
            busy / (2 * links_of_kind[kind] * makespan)
            if makespan > 0 and links_of_kind[kind]
            else 0.0
        )
        for kind, busy in link_busy.items()
    }
    phases = tuple(
        PhaseSpan(
            phase=name,
            start_s=phase_acc[name]["start"],
            end_s=phase_acc[name]["end"],
            sends=phase_acc[name]["sends"],
            hops=phase_acc[name]["hops"],
            electrical_bytes=phase_acc[name]["e_bytes"],
            optical_bytes=phase_acc[name]["o_bytes"],
            contention_events=phase_acc[name]["contention"],
        )
        for name in phase_order
    )
    return SimResult(
        total_time_s=makespan,
        messages=len(traces),
        hops=total_hops,
        rerouted_messages=rerouted_count,
        contention_events=contention_events,
        contention_wait_s=contention_wait,
        link_busy_s=dict(link_busy),
        link_utilization=utilization,
        max_link_busy_s=max(per_link_busy.values(), default=0.0),
        phases=phases,
        master_elems=held[topo.global_id(*master)],
        traces=tuple(traces),
    )


def simulate_gather(
    topo: OHHCTopology,
    *,
    link_model: LinkModel | None = None,
    router: Router | None = None,
    chunk_sizes: "Sequence[int] | int" = 1,
    itemsize: int = 4,
    barrier: bool = False,
) -> SimResult:
    """Build the paper's accumulation schedule for ``topo`` and simulate it."""
    return simulate_schedule(
        AccumulationSchedule.build(topo),
        topo,
        link_model=link_model,
        router=router,
        chunk_sizes=chunk_sizes,
        itemsize=itemsize,
        barrier=barrier,
    )


def critical_hop_count(result: SimResult, unit_s: float) -> int:
    """Hop count of the measured critical path under a unit link model."""
    return round(result.total_time_s / unit_s)
