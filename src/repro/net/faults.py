"""Link/node fault injection and degraded-schedule rebuilding (DESIGN.md §6).

OTIS networks keep working when individual transpose links die — the
fault-tolerance/Hamiltonicity analysis of arXiv:1109.1706 is the scenario
axis this module opens for the OHHC.  Two complementary mechanisms:

* **Implicit reroute** — hand ``simulate_schedule`` a faulted
  :class:`Router`; any send whose direct link is dead is transparently
  routed over a BFS-shortest alternative (store-and-forward, contention
  counted).  ``RouteError`` propagates when no alternative exists — the
  "fail" half of reroute-or-fail.

* **Explicit degraded schedule** — :func:`rebuild_degraded` rewrites the
  schedule itself: every send with a dead direct link becomes a chain of
  single-hop relay ``Send``s (phase tagged ``<phase>+reroute``), each in
  its own round.  The rebuilt schedule runs on the faulted graph with
  **zero** simulator-level reroutes, which is how tests cross-check the
  two mechanisms.  Relay sends follow *accumulation* semantics like every
  other ``Send``: a relay node forwards **everything it holds** — its own
  not-yet-sent chunk and any payload parked there by earlier rounds rides
  along (payload coalescing, the same wait-count discipline the paper's
  gather uses).  Delivery totals match the implicit mode exactly; the
  per-message byte timeline intentionally differs (coalesced vs carried
  end-to-end), which is itself a modelling choice worth comparing.

Node faults: a failed *leaf* (a node that only ever sends) loses its data
— the gather completes degraded, and the loss is visible in
``SimResult.master_elems``.  A failed *internal* node of the accumulation
tree (any send's destination) makes the gather impossible as scheduled,
and :func:`rebuild_degraded` raises :class:`GatherImpossible` instead of
silently dropping a subtree.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Iterable, Sequence

from repro.core.schedule import AccumulationSchedule, Send
from repro.core.topology import OHHCTopology

from repro.net.router import RouteError, Router

__all__ = [
    "GatherImpossible",
    "FaultScenario",
    "rebuild_degraded",
    "degraded_gather_rounds",
    "predicted_slowdown",
]


class GatherImpossible(RuntimeError):
    """The fault set breaks the accumulation tree beyond rerouting.

    ``nodes`` carries the offending *global ids* — the failed internal
    destinations, or the live nodes the fault set cut off from their
    scheduled destination — so callers can act on **which** part of the
    tree broke (the engine's fallback ladder, the fleet's worker mapping,
    tests) instead of parsing the message.
    """

    def __init__(self, message: str, *, nodes: Iterable[int] = ()):
        super().__init__(message)
        self.nodes = frozenset(int(n) for n in nodes)


@dataclasses.dataclass(frozen=True)
class FaultScenario:
    """A named set of dead links and nodes, in (group, local) addresses."""

    name: str = "healthy"
    failed_links: tuple = ()  # ((g, l), (g, l)) pairs, either order
    failed_nodes: tuple = ()  # (g, l) addresses

    @property
    def is_degraded(self) -> bool:
        """True when the scenario actually removes links or nodes."""
        return bool(self.failed_links or self.failed_nodes)

    def router(self, topo: OHHCTopology) -> Router:
        links = [
            (topo.global_id(*a), topo.global_id(*b)) for a, b in self.failed_links
        ]
        nodes = [topo.global_id(*n) for n in self.failed_nodes]
        return Router(topo, failed_links=links, failed_nodes=nodes)

    @classmethod
    def optical_link_down(cls, g: int) -> "FaultScenario":
        """The canonical scenario: group ``g``'s OTIS uplink (g,0)↔(0,g) dead."""
        if g == 0:
            # (0,0)↔(0,0) is the self-transpose hole, not a link — a "fault"
            # here would silently simulate the healthy network.
            raise ValueError("group 0 has no OTIS uplink to fail")
        return cls(
            name=f"optical_g{g}_down",
            failed_links=(((g, 0), (0, g)),),
        )

    @classmethod
    def worker_down(cls, w: int) -> "FaultScenario":
        """Serving-fleet vocabulary: fleet worker ``w`` ≡ OHHC group ``w``
        losing its hub node (g,0) — and with it, its OTIS uplink.

        This is the simulator-side twin of ``ChaosConfig`` killing fleet
        worker ``w`` (DESIGN.md §10): the group hub is an *internal*
        accumulation-tree destination, so ``rebuild_degraded`` raises
        :class:`GatherImpossible` — a dead worker cannot be routed around
        inside one gather, it must be drained and its work re-admitted,
        which is exactly the fleet's failover policy.  Contrast with
        :meth:`optical_link_down`, where only the uplink dies and relay
        chains reroute the gather.
        """
        if w < 0:
            raise ValueError("worker index must be >= 0")
        links = () if w == 0 else (((w, 0), (0, w)),)
        return cls(
            name=f"worker{w}_down",
            failed_links=links,
            failed_nodes=((w, 0),),
        )

    @classmethod
    def group_uplinks_down(cls, topo: OHHCTopology, g: int) -> "FaultScenario":
        """Every OTIS uplink of group ``g`` dead: the group stays
        electrically intact but optically islanded, so no payload can leave
        it — the canonical scenario :func:`rebuild_degraded` must refuse
        with the group's node set (it cannot be rerouted around)."""
        links = []
        for l in range(topo.procs_per_group):
            partner = topo.optical_partner(g, l)
            if partner is not None:
                links.append(((g, l), partner))
        if not links:
            raise ValueError(f"group {g} has no OTIS uplinks in this topology")
        return cls(name=f"uplinks_g{g}_down", failed_links=tuple(links))

    @classmethod
    def random_links(
        cls, topo: OHHCTopology, k: int, *, seed: int = 0
    ) -> "FaultScenario":
        """Seeded uniform draw of ``k`` dead links over the full (sorted)
        electrical+optical edge list — the k-link scenario axis the degraded
        verify grid, the property tests, and ``bench_faults`` share.  Same
        ``(topo, k, seed)`` ⇒ same scenario, on any host."""
        edges = sorted(
            {(min(a, b), max(a, b)) for a, b in topo.electrical_edges()}
            | {(min(a, b), max(a, b)) for a, b in topo.optical_edges()}
        )
        if not 0 <= k <= len(edges):
            raise ValueError(
                f"k={k} outside [0, {len(edges)}] links of this topology"
            )
        chosen = random.Random(seed).sample(edges, k)
        return cls(
            name=f"klinks{k}_s{seed}",
            failed_links=tuple(
                (topo.addr(a), topo.addr(b)) for a, b in sorted(chosen)
            ),
        )


def rebuild_degraded(
    schedule: "AccumulationSchedule | Sequence[Sequence[Send]]",
    topo: OHHCTopology,
    router: Router,
) -> tuple[tuple[Send, ...], ...]:
    """Rewrite ``schedule`` so every send uses only live direct links.

    Healthy sends keep their rounds; a send whose direct link is dead is
    replaced by its BFS relay chain, each hop appended as its own round
    right after the original round (store-and-forward order preserved, and
    later rounds — which depend on the payload's arrival — stay later).
    Sends *from* a failed leaf node are dropped (data loss, reported by the
    simulator); a failed internal node raises :class:`GatherImpossible`.

    The impossible verdict is all-at-once, never partial: before any
    rewriting, every send is checked for a live route, and a fault set that
    strands *any* live sender (e.g. all of a group's uplinks dead) raises
    :class:`GatherImpossible` whose ``nodes`` is the full cut-off
    component — not a partial schedule, and not a one-send message for a
    many-node disconnection.
    """
    rounds = (
        schedule.rounds
        if isinstance(schedule, AccumulationSchedule)
        else schedule
    )
    failed = set(router.failed_nodes)
    if failed:
        internal = {
            topo.global_id(*s.dst) for rnd in rounds for s in rnd
        } & failed
        if internal:
            raise GatherImpossible(
                f"failed node(s) {sorted(internal)} are accumulation-tree "
                "destinations; the gather cannot complete as scheduled",
                nodes=internal,
            )

    # Routability pre-pass: find every send the fault set strands, and
    # raise ONCE with the union of their cut-off components.
    stranded: set[int] = set()
    examples: list[str] = []
    for rnd in rounds:
        for s in rnd:
            src = topo.global_id(*s.src)
            dst = topo.global_id(*s.dst)
            if src in failed or src == dst:
                continue
            if router.link_kind(src, dst) is not None:
                continue
            try:
                router.shortest_path(src, dst)
            except RouteError:
                # the whole component around src is what the faults islanded
                stranded |= router.component(src)
                if len(examples) < 3:
                    examples.append(f"{s.src}→{s.dst} ({s.phase})")
    if stranded:
        raise GatherImpossible(
            f"fault set cuts node(s) {sorted(stranded)} off from their "
            f"scheduled destination (e.g. {', '.join(examples)}); "
            "the gather cannot be rerouted",
            nodes=stranded,
        )

    out: list[tuple[Send, ...]] = []
    for rnd in rounds:
        direct: list[Send] = []
        relay_chains: list[list[Send]] = []
        for s in rnd:
            src = topo.global_id(*s.src)
            dst = topo.global_id(*s.dst)
            if src in failed:
                continue  # dead leaf: its payload is lost, gather degrades
            if src == dst or router.link_kind(src, dst) is not None:
                # self-sends deliver in place in the simulator; never let
                # one fall through to shortest_path's empty hop list (a
                # zero-hop "relay chain" would silently drop the send)
                direct.append(s)
                continue
            hops = router.shortest_path(src, dst)  # pre-pass proved it routes
            relay_chains.append(
                [
                    Send(topo.addr(u), topo.addr(v), kind, f"{s.phase}+reroute")
                    for u, v, kind in hops
                ]
            )
        if direct:
            out.append(tuple(direct))
        # Interleave relay hops as follow-on rounds: hop k of every chain
        # shares round slot k (chains are link-disjoint per hop or the
        # simulator's occupancy serialises them).
        depth = max((len(c) for c in relay_chains), default=0)
        for k in range(depth):
            out.append(tuple(c[k] for c in relay_chains if len(c) > k))
    return tuple(r for r in out if r)


def degraded_gather_rounds(
    topo: OHHCTopology, scenario: FaultScenario
) -> tuple[tuple[Send, ...], ...]:
    """Paper schedule → degraded rounds for ``scenario`` (convenience)."""
    return rebuild_degraded(
        AccumulationSchedule.build(topo), topo, scenario.router(topo)
    )


def predicted_slowdown(
    topo: OHHCTopology,
    scenario: FaultScenario,
    *,
    chunk_sizes: "int | Sequence[int]",
    itemsize: int = 4,
    link_model=None,
    barrier: bool = True,
) -> tuple[float, float, float]:
    """``(healthy_s, degraded_s, ratio)`` for one gather under ``scenario``.

    Both sides run the event-driven simulator (``repro.net.sim``) over the
    same chunk sizes: the healthy side on the paper schedule, the degraded
    side on :func:`rebuild_degraded`'s rewrite with the scenario's faulted
    router.  ``barrier=True`` is the paper's BSP accounting — the number
    the engine quotes as *predicted* slowdown in ``SortPlan.reason`` and
    ``bench_faults`` gates the *measured* (dependency-mode, contention-
    aware) ratio against.  Raises :class:`GatherImpossible` when the
    scenario cannot gather at all.
    """
    from repro.net.links import LinkModel
    from repro.net.sim import simulate_gather, simulate_schedule

    lm = link_model if link_model is not None else LinkModel()
    healthy = simulate_gather(
        topo,
        link_model=lm,
        chunk_sizes=chunk_sizes,
        itemsize=itemsize,
        barrier=barrier,
    ).total_time_s
    router = scenario.router(topo)
    rounds = rebuild_degraded(AccumulationSchedule.build(topo), topo, router)
    degraded = simulate_schedule(
        rounds,
        topo,
        link_model=lm,
        router=router,
        chunk_sizes=chunk_sizes,
        itemsize=itemsize,
        barrier=barrier,
    ).total_time_s
    return healthy, degraded, degraded / healthy
