"""Simulated-vs-analytic validation reports for the OHHC netsim.

For every requested (d_h, variant) this module runs the gather three ways
and cross-checks them (DESIGN.md §6 validation methodology):

1. **unit model, barrier mode** — every hop costs one unit and rounds are
   BSP barriers (the paper's accounting): the measured makespan must equal
   the schedule's critical-path round count ``2·d_h + 3`` exactly;
2. **unit model, dependency mode** — nodes forward as soon as their wait
   count is met: full-variant makespan still ``2·d_h + 3`` rounds; the
   **half** variant finishes in ``2·d_h + 2`` — one round of slack, a
   reproduction finding (its optical-hole nodes ``local ≥ G`` receive no
   optical payload, so the first D-round never waits for Phase C);
3. **default byte model** — measured makespan vs the analytic
   store-and-forward sum ``model_comm_time_s(..., roundtrip=False)``:
   exact in barrier mode, ≤ analytic in dependency mode;
4. **one optical fault** — ``FaultScenario.optical_link_down(g)``: the
   gather must still complete (every chunk reaches the master) with a
   reported slowdown and reroute/contention counters.

Output is a plain dict (JSON-safe), with ``to_markdown`` for humans and
``write_json`` for the CI artifact.
"""

from __future__ import annotations

import json
import math
import pathlib

from repro.core.ohhc_sort import model_comm_time_s
from repro.core.schedule import AccumulationSchedule
from repro.core.topology import OHHCTopology

from repro.net.faults import FaultScenario
from repro.net.links import LinkModel
from repro.net.router import Router
from repro.net.sim import critical_hop_count, simulate_schedule

UNIT_US = 1.0


def _json_safe(d: dict) -> dict:
    """Strict-JSON view: non-finite floats (inf bandwidth) become strings."""
    return {
        k: (v if not isinstance(v, float) or math.isfinite(v) else str(v))
        for k, v in d.items()
    }


def case_report(
    d_h: int,
    variant: str,
    *,
    link_model: LinkModel | None = None,
    chunk_elems: int = 1024,
    itemsize: int = 4,
    fault_group: int = 1,
) -> dict:
    """One (d_h, variant) validation row; see module docstring for the axes."""
    link_model = link_model if link_model is not None else LinkModel()
    topo = OHHCTopology(d_h, variant)
    sched = AccumulationSchedule.build(topo)
    router = Router(topo)

    diam = router.verify_diameter()
    unit_lm = LinkModel.unit(UNIT_US)
    unit_barrier = simulate_schedule(
        sched, topo, link_model=unit_lm, router=router,
        chunk_sizes=chunk_elems, itemsize=itemsize, barrier=True,
    )
    unit_dep = simulate_schedule(
        sched, topo, link_model=unit_lm, router=router,
        chunk_sizes=chunk_elems, itemsize=itemsize,
    )
    barrier_rounds = critical_hop_count(unit_barrier, UNIT_US * 1e-6)
    dep_rounds = critical_hop_count(unit_dep, UNIT_US * 1e-6)

    healthy = simulate_schedule(
        sched, topo, link_model=link_model, router=router,
        chunk_sizes=chunk_elems, itemsize=itemsize, barrier=True,
    )
    analytic_s = model_comm_time_s(
        sched,
        [chunk_elems] * topo.total_procs,
        link_model.to_core(),
        itemsize=itemsize,
        roundtrip=False,
    )
    delta = (
        abs(healthy.total_time_s - analytic_s) / analytic_s
        if analytic_s > 0
        else 0.0
    )

    # Map into 1..G-1: group 0 has no OTIS uplink, so a modulo that lands
    # on 0 would silently simulate the healthy network as the "fault".
    scenario = FaultScenario.optical_link_down(
        1 + (fault_group - 1) % (topo.num_groups - 1)
    )
    faulted = simulate_schedule(
        sched, topo, link_model=link_model, router=scenario.router(topo),
        chunk_sizes=chunk_elems, itemsize=itemsize, barrier=True,
    )
    return {
        "d_h": d_h,
        "variant": variant,
        "total_procs": topo.total_procs,
        "diameter_measured": diam["measured"],
        "diameter_expected": diam["expected"],
        "eccentricity_radius": diam["radius"],
        "critical_rounds_schedule": sched.critical_path_rounds(),
        "critical_rounds_simulated": barrier_rounds,
        "dependency_rounds": dep_rounds,
        "dependency_slack_rounds": barrier_rounds - dep_rounds,
        "paper_step_count": sched.paper_step_count(),
        "tree_sends": sched.tree_send_count(),
        "sim_time_us": healthy.total_time_s * 1e6,
        "analytic_time_us": analytic_s * 1e6,
        "sim_vs_analytic_delta": delta,
        "contention_events": healthy.contention_events,
        "link_utilization": healthy.link_utilization,
        "master_elems": healthy.master_elems,
        "fault": {
            "scenario": scenario.name,
            "completed": faulted.master_elems == healthy.master_elems,
            "sim_time_us": faulted.total_time_s * 1e6,
            "slowdown": (
                faulted.total_time_s / healthy.total_time_s
                if healthy.total_time_s > 0
                else 1.0
            ),
            "rerouted_messages": faulted.rerouted_messages,
            "contention_events": faulted.contention_events,
        },
    }


def netsim_report(
    dims=(1, 2, 3),
    variants=("full", "half"),
    *,
    link_model: LinkModel | None = None,
    chunk_elems: int = 1024,
    itemsize: int = 4,
    fault_group: int = 1,
) -> dict:
    link_model = link_model if link_model is not None else LinkModel()
    cases = [
        case_report(
            d_h,
            variant,
            link_model=link_model,
            chunk_elems=chunk_elems,
            itemsize=itemsize,
            fault_group=fault_group,
        )
        for variant in variants
        for d_h in dims
    ]
    return {
        "chunk_elems": chunk_elems,
        "itemsize": itemsize,
        "link_model": {
            "electrical": _json_safe(vars(link_model.electrical)),
            "optical": _json_safe(vars(link_model.optical)),
        },
        "all_rounds_validated": all(
            c["critical_rounds_simulated"] == c["critical_rounds_schedule"]
            for c in cases
        ),
        "all_diameters_validated": all(
            c["diameter_measured"] == c["diameter_expected"] for c in cases
        ),
        "all_faults_completed": all(c["fault"]["completed"] for c in cases),
        "cases": cases,
    }


def to_markdown(report: dict) -> str:
    lines = [
        "# netsim — simulated vs analytic gather validation",
        "",
        f"chunk = {report['chunk_elems']} × {report['itemsize']} B, "
        f"rounds validated: {report['all_rounds_validated']}, "
        f"diameters validated: {report['all_diameters_validated']}, "
        f"faults completed: {report['all_faults_completed']}",
        "",
        "| d_h | variant | P | diam (meas/exp) | rounds (sim/sched) | "
        "sim µs | analytic µs | Δ | fault slowdown | reroutes |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in report["cases"]:
        lines.append(
            f"| {c['d_h']} | {c['variant']} | {c['total_procs']} "
            f"| {c['diameter_measured']}/{c['diameter_expected']} "
            f"| {c['critical_rounds_simulated']}/{c['critical_rounds_schedule']} "
            f"| {c['sim_time_us']:.1f} | {c['analytic_time_us']:.1f} "
            f"| {c['sim_vs_analytic_delta']:.2%} "
            f"| {c['fault']['slowdown']:.2f}x "
            f"| {c['fault']['rerouted_messages']} |"
        )
    return "\n".join(lines) + "\n"


def write_json(report: dict, path: "str | pathlib.Path") -> pathlib.Path:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(report, indent=2, sort_keys=True))
    return p
