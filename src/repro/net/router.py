"""BFS shortest-path routing over the OHHC link graph (DESIGN.md §6).

Builds the *link-level* adjacency from ``OHHCTopology.electrical_edges()``
/ ``optical_edges()`` (optionally minus failed links/nodes) and answers
routing queries for the event-driven simulator:

* ``shortest_path(src, dst)`` — hop list ``[(u, v, kind), ...]`` with each
  hop labelled electrical/optical, BFS (unit-weight) shortest;
* ``eccentricity`` / ``eccentricities`` / ``diameter`` — the graph-metric
  cross-checks: the healthy OHHC diameter must equal ``2·d_h + 3``
  (OTIS rule ``2·d(factor) + 1`` with HHC diameter ``d_h + 1``; the
  eccentricity-of-OTIS-nodes analysis of arXiv:1310.7376 motivates
  checking the whole eccentricity profile, not just its max);
* ``verify_diameter()`` — measured vs expected, used by tests and the
  netsim report.

Addresses are global ids (``topo.global_id``); links are canonical
``(min_gid, max_gid)`` tuples.
"""

from __future__ import annotations

import collections
from typing import Iterable

from repro.core.topology import OHHCTopology

from repro.net.links import ELECTRICAL, OPTICAL


class RouteError(RuntimeError):
    """No route exists between two endpoints (disconnection after faults)."""


def canonical_link(a: int, b: int) -> tuple[int, int]:
    return (a, b) if a < b else (b, a)


class Router:
    """Adjacency + BFS routing, with optional failed links/nodes removed.

    ``failed_links`` is an iterable of gid pairs (either order);
    ``failed_nodes`` an iterable of gids.  A failed node keeps its id but
    loses every incident link (it becomes unreachable, and any route
    through it is forbidden).
    """

    def __init__(
        self,
        topo: OHHCTopology,
        *,
        failed_links: Iterable[tuple[int, int]] = (),
        failed_nodes: Iterable[int] = (),
    ):
        self.topo = topo
        self.failed_links = frozenset(canonical_link(*l) for l in failed_links)
        self.failed_nodes = frozenset(int(n) for n in failed_nodes)
        adj: dict[int, list[tuple[int, str]]] = {
            gid: [] for gid in range(topo.total_procs)
        }
        self._kinds: dict[tuple[int, int], str] = {}
        for kind, edges in (
            (ELECTRICAL, topo.electrical_edges()),
            (OPTICAL, topo.optical_edges()),
        ):
            for a, b in edges:
                if canonical_link(a, b) in self.failed_links:
                    continue
                if a in self.failed_nodes or b in self.failed_nodes:
                    continue
                adj[a].append((b, kind))
                adj[b].append((a, kind))
                self._kinds[canonical_link(a, b)] = kind
        self.adjacency = {g: tuple(sorted(ns)) for g, ns in adj.items()}
        self._bfs_cache: dict[int, tuple[dict[int, int], dict[int, int]]] = {}

    # ---- queries ------------------------------------------------------------
    def neighbors(self, gid: int) -> tuple[tuple[int, str], ...]:
        return self.adjacency[gid]

    def link_kind(self, a: int, b: int) -> str | None:
        """Link class of a live edge, or None when absent/failed."""
        return self._kinds.get(canonical_link(a, b))

    def live_links(self) -> dict[tuple[int, int], str]:
        return dict(self._kinds)

    def _bfs(self, src: int) -> tuple[dict[int, int], dict[int, int]]:
        cached = self._bfs_cache.get(src)
        if cached is not None:
            return cached
        dist, parent = {src: 0}, {src: src}
        q = collections.deque([src])
        while q:
            u = q.popleft()
            for v, _ in self.adjacency[u]:
                if v not in dist:
                    dist[v] = dist[u] + 1
                    parent[v] = u
                    q.append(v)
        self._bfs_cache[src] = (dist, parent)
        return dist, parent

    def distance(self, src: int, dst: int) -> int:
        dist, _ = self._bfs(src)
        if dst not in dist:
            raise RouteError(f"no route {src} → {dst}")
        return dist[dst]

    def shortest_path(self, src: int, dst: int) -> list[tuple[int, int, str]]:
        """Hop list [(u, v, kind), ...] along one BFS-shortest route."""
        if src == dst:
            return []
        dist, parent = self._bfs(src)
        if dst not in dist:
            raise RouteError(f"no route {src} → {dst}")
        hops: list[tuple[int, int, str]] = []
        v = dst
        while v != src:
            u = parent[v]
            hops.append((u, v, self._kinds[canonical_link(u, v)]))
            v = u
        hops.reverse()
        return hops

    def component(self, gid: int) -> frozenset:
        """Live nodes reachable from ``gid`` (itself included) — the island
        a fault set strands a sender on (``net.faults`` reports it whole)."""
        dist, _ = self._bfs(gid)
        return frozenset(dist)

    # ---- graph metrics ------------------------------------------------------
    def is_connected(self) -> bool:
        live = [g for g in self.adjacency if g not in self.failed_nodes]
        if not live:
            return True
        dist, _ = self._bfs(live[0])
        return all(g in dist for g in live)

    def eccentricity(self, gid: int) -> int:
        """Max BFS distance from ``gid`` over all *reachable* live nodes."""
        dist, _ = self._bfs(gid)
        live = {g for g in dist if g not in self.failed_nodes}
        return max(dist[g] for g in live)

    def eccentricities(self) -> dict[int, int]:
        return {
            gid: self.eccentricity(gid)
            for gid in self.adjacency
            if gid not in self.failed_nodes
        }

    def diameter(self) -> int:
        return max(self.eccentricities().values())

    def expected_diameter(self) -> int:
        """Healthy-OHHC closed form: 2·d_h + 3."""
        return 2 * self.topo.d_h + 3

    def verify_diameter(self) -> dict:
        """Measured vs closed-form diameter + the eccentricity profile."""
        eccs = self.eccentricities()
        measured = max(eccs.values())
        expected = self.expected_diameter()
        profile = collections.Counter(eccs.values())
        return {
            "measured": measured,
            "expected": expected,
            "ok": measured == expected and not self.failed_links
            and not self.failed_nodes,
            "radius": min(eccs.values()),
            "eccentricity_histogram": dict(sorted(profile.items())),
        }
