"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) the kernels run with ``interpret=True`` — the
kernel body executes in Python for correctness validation; on TPU they
compile to Mosaic.  ``interpret=None`` auto-detects.

``local_sort`` handles arbitrary lengths: pad → power-of-two tiles →
in-VMEM bitonic sort per tile → **merge-splitting network** across tiles
(odd-even transposition over sorted blocks with the two-tile bitonic merge
as the comparator — a classic block-sorting network, correct for any
number of tiles in ``num_tiles`` passes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bitonic
from repro.kernels.partition_kernel import bucket_count_rank as _bcr

# One tile ≤ 2**19 f32 = 2 MiB: tile + the network's temporaries stay well
# under the 16 MiB VMEM budget.
MAX_TILE = 1 << 19
MIN_TILE = bitonic.LANES  # 128


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


def _fill_value(dtype):
    return jnp.iinfo(dtype).max if jnp.issubdtype(dtype, jnp.integer) else jnp.array(
        jnp.inf, dtype
    )


def _next_pow2(n: int) -> int:
    return 1 << max((n - 1).bit_length(), 0)


def bucketed_length(n: int, *, min_size: int = MIN_TILE) -> int:
    """Power-of-two shape bucket for ``n`` (≥ ``min_size``).

    The shared shape-bucketing rule: the bitonic kernels pad to this length
    internally, and ``repro.core.engine.SortEngine`` keys its warm jit cache
    on it so any two lengths in the same bucket reuse one compilation.
    """
    return max(_next_pow2(max(n, 1)), min_size)


def local_sort(x: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Sort a flat array with the bitonic kernel(s).  Returns same length.

    Pads to the shape bucket with the dtype max, so the pad tail sorts to
    the end and slicing ``[:n]`` recovers the sorted input.
    """
    interpret = _auto_interpret(interpret)
    n = x.shape[0]
    if n <= 1:
        return x
    n_pad = bucketed_length(n)
    xp = jnp.concatenate([x, jnp.full((n_pad - n,), _fill_value(x.dtype), x.dtype)])
    if n_pad <= MAX_TILE:
        return bitonic.sort_tile(xp, interpret=interpret)[:n]
    # ---- multi-tile: sort tiles, then merge-splitting passes
    num_tiles = n_pad // MAX_TILE
    tiles = [
        bitonic.sort_tile(xp[i * MAX_TILE : (i + 1) * MAX_TILE], interpret=interpret)
        for i in range(num_tiles)
    ]
    # Odd-even transposition over sorted blocks: with the two-tile merge as
    # comparator, ``num_tiles`` *alternating half-passes* (even, odd, even, …)
    # already sort any block arrangement — a full even+odd pair per round
    # would double the merge count for nothing.
    for p in range(num_tiles):
        for i in range(p % 2, num_tiles - 1, 2):
            lo, hi = bitonic.merge_tiles(tiles[i], tiles[i + 1], interpret=interpret)
            tiles[i], tiles[i + 1] = lo, hi
    return jnp.concatenate(tiles)[:n]


def local_sort_pairs(
    keys: jax.Array,
    vals: jax.Array,
    *,
    n_valid: jax.Array | int | None = None,
    interpret: bool | None = None,
):
    """Sort (key, payload) pairs by key.  Single-tile sizes (≤ MAX_TILE).

    Sentinel-safe: pad slots carry a validity tag that breaks key ties, so
    real elements whose keys equal the dtype-max pad sentinel keep their
    payloads ahead of the zero-payload pad tail (the ``[:n]`` slice can
    never cut a real payload).  ``n_valid`` (default ``len(keys)``) marks
    where validity ends when the caller pre-padded; it may be traced, so a
    warm executable serves every length in the shape bucket.
    """
    interpret = _auto_interpret(interpret)
    n = keys.shape[0]
    n_pad = bucketed_length(n)
    if n_pad > MAX_TILE:
        raise ValueError(f"local_sort_pairs supports n ≤ {MAX_TILE}, got {n}")
    if n_valid is None:
        n_valid = n
    kp = jnp.concatenate(
        [keys, jnp.full((n_pad - n,), _fill_value(keys.dtype), keys.dtype)]
    )
    vp = jnp.concatenate([vals, jnp.zeros((n_pad - n,), vals.dtype)])
    tags = (jnp.arange(n_pad, dtype=jnp.int32) >= n_valid).astype(jnp.int32)
    ks, vs = bitonic.sort_pairs_tile_tagged(kp, tags, vp, interpret=interpret)
    return ks[:n], vs[:n]


def bucket_count_rank(
    ids: jax.Array,
    num_buckets: int,
    *,
    tile: int = 1024,
    interpret: bool | None = None,
    debug: bool = False,
):
    """Histogram + stable in-bucket ranks (see partition_kernel)."""
    return _bcr(
        ids, num_buckets, tile=tile, interpret=_auto_interpret(interpret), debug=debug
    )


def make_local_sort(interpret: bool | None = None):
    """A partial suitable as the ``local_sort=`` argument of the core sorts."""
    return functools.partial(local_sort, interpret=interpret)
