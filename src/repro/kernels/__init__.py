"""Pallas TPU kernels for the paper's compute hot-spots.

The paper's per-processor hot loop is the local sort; its partition step
is the bucket histogram/scatter.  TPU-native equivalents (DESIGN.md §2):

* ``bitonic``          — in-VMEM bitonic sort / pair-sort / two-tile merge
                         (reshape-based compare-exchange, zero gathers)
* ``partition_kernel`` — bucket histogram + stable ranks (one-hot form,
                         sequential-grid running offsets)
* ``ops``              — jit'd wrappers (interpret=True on CPU)
* ``ref``              — pure-jnp oracles for the allclose tests
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
