"""Pallas TPU kernels for the paper's compute hot-spots.

The paper's per-processor hot loop is the local sort; its partition step
is the bucket histogram/scatter.  TPU-native equivalents (DESIGN.md §2):

* ``bitonic``          — in-VMEM bitonic sort / pair-sort / two-tile merge
                         (reshape-based compare-exchange, zero gathers)
* ``batched``          — fused batched segmented row sort: one pallas_call,
                         grid over the batch axis, sentinel-fill + sort +
                         validity mask per row (the serving hot path)
* ``partition_kernel`` — bucket histogram + stable ranks (one-hot form,
                         sequential-grid running offsets)
* ``ops``              — jit'd wrappers (interpret=True on CPU)
* ``ref``              — pure-jnp oracles for the allclose tests
"""

from repro.kernels import batched, ops, ref

__all__ = ["batched", "ops", "ref"]
