"""Pallas TPU kernel: fused batched segmented row sort (DESIGN.md §2, §8).

The engine's hottest serving primitive — ``sort_segments``' sentinel-padded
``(B, Lbucket)`` row sort — as ONE ``pallas_call`` with the grid over the
batch axis.  Each grid step sorts one row entirely in VMEM:

* **sentinel-fill is fused**: the per-row valid length arrives as a
  ``seg_lens`` scalar-prefetch operand (SMEM-resident, available before the
  row's VMEM block streams in), and the kernel masks positions ``≥ len`` to
  the dtype-max sentinel itself — whatever garbage the pad cells carry on
  entry, so the host-side pad fill and the separate mask pass disappear;
* the row then runs the same reshape-based compare-exchange network as
  ``bitonic.py`` (zero gathers, every stage a full-width VPU op);
* the masked fill doubles as the **validity mask** on the way out: pad
  cells leave the kernel holding the sentinel, so row ``i``'s sorted
  segment is exactly ``out[i, :seg_lens[i]]``.

Two compare-exchange primitives are selectable per plan:

* ``method="bitonic"`` — the classic 4-op stage (min, max, 2 selects);
* ``method="bitonic2op"`` — Paeth's NICE-network "2-op" stage:
  ``mn = min(a, b); mx = a + b - mn``.  The sum wraps modulo 2**w in
  two's-complement, so ``a + b - mn`` is *exactly* ``max(a, b)`` for every
  integer dtype — one op fewer per exchange and no select chain.  Floats
  have no such identity (rounding breaks it), so float dtypes silently use
  the 4-op stage; ``METHODS`` names both variants.

``batched_row_sort_pairs`` is the (key, payload) variant for
``sort_pairs``/MoE dispatch: validity rides as a tag bit through the
lexicographic ``(tag, key)`` exchange (``bitonic._compare_exchange_tagged``),
so pad slots sort strictly after real ones even when real keys equal the
dtype-max sentinel — payloads cannot be lost to the pad tail.

Rows must be power-of-two multiples of 128 lanes (``ops.bucketed_length``
guarantees this for every engine caller); the batch axis is the grid, so
any ``B ≥ 1`` works.  On CPU the kernels run with ``interpret=True``; on
TPU they compile to Mosaic with the row block ``(1, L/128, 128)`` resident
in VMEM (L ≤ ``SEGMENT_BITONIC_MAX`` = 8192 keeps a f32 row ≤ 32 KiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import bitonic
from repro.kernels.bitonic import LANES, _log2

__all__ = ["batched_row_sort", "batched_row_sort_pairs", "METHODS"]

# The selectable compare-exchange variants (see module docstring).
METHODS = ("bitonic", "bitonic2op")


def _sentinel(dtype):
    # typed scalar — a weak Python int overflows jnp.where for uint dtypes
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.array(jnp.iinfo(dtype).max, dtype)
    return jnp.array(jnp.inf, dtype)


def _positions(r: int) -> jax.Array:
    """Flat element positions of an ``(r, LANES)`` row view, 2-D iota only
    (1-D iota does not lower on TPU)."""
    row = jax.lax.broadcasted_iota(jnp.int32, (r, LANES), 0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (r, LANES), 1)
    return row * LANES + lane


def _compare_exchange_2op(x: jax.Array, s: int, j: int) -> jax.Array:
    """Paeth NICE stage: ``mn = min(a,b); mx = a + b - mn`` (ints, exact
    under modular wraparound).  Same reshape/direction scheme as
    ``bitonic._compare_exchange``."""
    n = x.shape[0]
    d = 1 << j
    y = x.reshape(n // (2 * d), 2, d)
    a, b = y[:, 0, :], y[:, 1, :]
    q = jnp.arange(n // (2 * d), dtype=jnp.int32)
    asc = (((q >> (s - j)) & 1) == 0)[:, None]
    mn = jnp.minimum(a, b)
    mx = a + b - mn
    lo = jnp.where(asc, mn, mx)
    hi = jnp.where(asc, mx, mn)
    return jnp.stack([lo, hi], axis=1).reshape(n)


def _row_network(x: jax.Array, *, two_op: bool) -> jax.Array:
    stage = (
        _compare_exchange_2op
        if two_op and jnp.issubdtype(x.dtype, jnp.integer)
        else bitonic._compare_exchange
    )
    kbits = _log2(x.shape[0])
    for s in range(kbits):
        for j in range(s, -1, -1):
            x = stage(x, s, j)
    return x


# ----------------------------------------------------------------- kernels
def batched_row_sort_kernel(len_ref, x_ref, o_ref, *, two_op: bool):
    """One grid step = one row: fused sentinel-fill + sort + validity mask."""
    r = x_ref.shape[1]
    n = r * LANES
    length = len_ref[pl.program_id(0)]
    pos = _positions(r)
    x = jnp.where(pos < length, x_ref[0], _sentinel(x_ref.dtype))
    o_ref[0] = _row_network(x.reshape(n), two_op=two_op).reshape(r, LANES)


def batched_row_sort_pairs_kernel(len_ref, k_ref, v_ref, ok_ref, ov_ref):
    """Pairs row sort; validity fused as the tag bit of the lexicographic
    ``(tag, key)`` exchange — sentinel-tie safe by construction."""
    r = k_ref.shape[1]
    n = r * LANES
    length = len_ref[pl.program_id(0)]
    pos = _positions(r)
    valid = pos < length
    keys = jnp.where(valid, k_ref[0], _sentinel(k_ref.dtype)).reshape(n)
    tags = (~valid).astype(jnp.int32).reshape(n)
    vals = jnp.where(valid, v_ref[0], jnp.zeros((), v_ref.dtype)).reshape(n)
    kbits = _log2(n)
    for s in range(kbits):
        for j in range(s, -1, -1):
            keys, tags, vals = bitonic._compare_exchange_tagged(
                keys, tags, vals, s, j
            )
    ok_ref[0] = keys.reshape(r, LANES)
    ov_ref[0] = vals.reshape(r, LANES)


# ------------------------------------------------------------ pallas_call
def _row_block(b_shape: tuple[int, int]) -> tuple[int, int, int]:
    B, L = b_shape
    if L % LANES or L & (L - 1):
        raise ValueError(f"row length {L} must be a power-of-two multiple of {LANES}")
    return (1, L // LANES, LANES)


@functools.partial(jax.jit, static_argnames=("method", "interpret"))
def batched_row_sort(
    padded: jax.Array,
    seg_lens: jax.Array,
    *,
    method: str = "bitonic",
    interpret: bool = False,
) -> jax.Array:
    """Sort every row of ``padded (B, L)`` to its ``seg_lens`` valid length.

    One ``pallas_call``, grid ``(B,)``, ``seg_lens`` scalar-prefetched:
    row ``i`` of the result is ``sorted(padded[i, :seg_lens[i]])`` followed
    by a dtype-max sentinel tail.  Pad-cell *input* contents are ignored —
    the kernel refills them — so callers can pack rows with anything.
    """
    if method not in METHODS:
        raise ValueError(f"method {method!r} not in {METHODS}")
    B, L = padded.shape
    block = _row_block((B, L))
    r = block[1]
    x3 = padded.reshape(B, r, LANES)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[pl.BlockSpec(block, lambda b, lens: (b, 0, 0))],
        out_specs=pl.BlockSpec(block, lambda b, lens: (b, 0, 0)),
    )
    out = pl.pallas_call(
        functools.partial(batched_row_sort_kernel, two_op=method == "bitonic2op"),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, r, LANES), padded.dtype),
        interpret=interpret,
    )(seg_lens.astype(jnp.int32), x3)
    return out.reshape(B, L)


@functools.partial(jax.jit, static_argnames=("interpret",))
def batched_row_sort_pairs(
    keys: jax.Array,
    vals: jax.Array,
    seg_lens: jax.Array,
    *,
    interpret: bool = False,
):
    """Row-sort ``(B, L)`` key/payload pairs by key to ``seg_lens`` lengths.

    Sentinel-tie safe: validity is a fused tag bit, so dtype-max keys keep
    their payloads (the pad tail carries sentinel keys + zero payloads).
    """
    B, L = keys.shape
    block = _row_block((B, L))
    r = block[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[pl.BlockSpec(block, lambda b, lens: (b, 0, 0))] * 2,
        out_specs=[pl.BlockSpec(block, lambda b, lens: (b, 0, 0))] * 2,
    )
    ok, ov = pl.pallas_call(
        batched_row_sort_pairs_kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((B, r, LANES), keys.dtype),
            jax.ShapeDtypeStruct((B, r, LANES), vals.dtype),
        ),
        interpret=interpret,
    )(
        seg_lens.astype(jnp.int32),
        keys.reshape(B, r, LANES),
        vals.reshape(B, r, LANES),
    )
    return ok.reshape(B, L), ov.reshape(B, L)
