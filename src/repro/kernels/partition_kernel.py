"""Pallas TPU kernel: bucket histogram + stable in-bucket ranks.

This is the compute hot spot of the Array Division Procedure (§3.1) and of
the MoE sort-based dispatch: given per-element bucket ids, produce

* ``counts[b]``  — population of bucket ``b`` (histogram), and
* ``ranks[i]``   — #{j < i : ids[j] == ids[i]} (stable scatter offsets).

Formulation is branch- and gather-free: the tile's ids expand to a one-hot
matrix ``H (T×B)``; ``counts = Σ_rows H`` and the in-tile rank is
``((exclusive-cumsum_rows H) ∘ H)·1`` — an elementwise product and a row
sum, so everything maps onto the VPU (and the cumsum could be an MXU
triangular matmul; XLA lowers ``cumsum`` to a log-depth scan which is
already bandwidth-optimal for T ≤ 2**14).

The grid walks tiles **sequentially** (TPU grid semantics): the counts
block is revisited every step and doubles as the running cross-tile offset,
so ranks are global without a second pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def bucket_count_rank_kernel(ids_ref, counts_ref, ranks_ref):
    num_buckets = counts_ref.shape[1]
    tile = ids_ref.shape[0]

    @pl.when(pl.program_id(0) == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    ids = ids_ref[...].reshape(tile)
    onehot = (ids[:, None] == jnp.arange(num_buckets, dtype=ids.dtype)[None, :]).astype(
        jnp.int32
    )  # (T, B)
    base = counts_ref[...].reshape(num_buckets)  # running counts from prior tiles
    excl = jnp.cumsum(onehot, axis=0) - onehot  # exclusive in-tile cumsum
    rank_in_tile = jnp.sum(excl * onehot, axis=1)
    base_per_elem = jnp.sum(base[None, :] * onehot, axis=1)
    ranks_ref[...] = (rank_in_tile + base_per_elem).reshape(tile, 1)
    counts_ref[...] = (base + jnp.sum(onehot, axis=0)).reshape(1, num_buckets)


def bucket_count_rank(
    ids: jax.Array,
    num_buckets: int,
    *,
    tile: int = 1024,
    interpret: bool = False,
    debug: bool = False,
):
    """Histogram + stable ranks for ``ids`` (flat int32 in [0, num_buckets)).

    Pads to a tile multiple internally; padded slots use bucket id
    ``num_buckets - 1`` but their ranks are discarded and counts corrected.
    ``n == 0`` short-circuits to empty results (a ``grid=(0,)`` pallas_call
    is ill-formed).  ``debug=True`` validates the id range eagerly on the
    host (concrete inputs only — out-of-range ids otherwise match no
    one-hot column and silently under-count).
    """
    if ids.shape[0] == 0:
        return (
            jnp.zeros((num_buckets,), jnp.int32),
            jnp.zeros((0,), jnp.int32),
        )
    if debug:
        ids_np = jax.device_get(ids)
        bad = (ids_np < 0) | (ids_np >= num_buckets)
        if bad.any():
            offenders = ids_np[bad][:8]
            raise ValueError(
                f"bucket ids out of range [0, {num_buckets}): {offenders!r}"
            )
    return _bucket_count_rank_impl(ids, num_buckets, tile=tile, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_buckets", "tile", "interpret"))
def _bucket_count_rank_impl(
    ids: jax.Array, num_buckets: int, *, tile: int = 1024, interpret: bool = False
):
    n = ids.shape[0]
    n_pad = -(-n // tile) * tile
    pad = n_pad - n
    ids_p = jnp.concatenate(
        [ids.astype(jnp.int32), jnp.full((pad,), num_buckets - 1, jnp.int32)]
    )
    counts, ranks = pl.pallas_call(
        bucket_count_rank_kernel,
        grid=(n_pad // tile,),
        in_specs=[pl.BlockSpec((tile, 1), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((1, num_buckets), lambda i: (0, 0)),
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((1, num_buckets), jnp.int32),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
        ),
        interpret=interpret,
    )(ids_p.reshape(n_pad, 1))
    counts = counts.reshape(num_buckets)
    if pad:
        counts = counts.at[num_buckets - 1].add(-pad)
    return counts, ranks.reshape(n_pad)[:n]
