"""Pallas TPU kernel: in-VMEM bitonic sort (the local-sort hot spot).

Hardware adaptation (DESIGN.md §2): the paper's per-processor *sequential
Quick Sort* is branch-heavy and pointer-chasing — dead on a vector unit.
The TPU-native equivalent is a **bitonic sorting network**: the
compare-exchange pattern is a pure function of the index, so every stage is
a full-width VPU op on a VMEM-resident tile.

Key implementation trick — *reshape-based compare-exchange, zero gathers*:
a stage at distance ``d`` pairs index ``i`` with ``i ⊕ d``.  Viewing the
flat array as ``(N/2d, 2, d)``, the two partners are the two slices of the
middle axis, and the ascending/descending direction of block ``s`` depends
only on the leading-axis index — everything is reshapes, ``min``/``max``
and a broadcast ``where``.  No scatter/gather units touched.

Kernels
-------
* ``bitonic_sort_kernel``        — sort one VMEM tile of 2**k keys.
* ``bitonic_sort_pairs_kernel``  — sort (key, payload) pairs (used by the
  MoE dispatch: payload = token index).
* ``bitonic_merge_kernel``       — merge two sorted tiles (concat with one
  reversed = bitonic sequence → log(2L) merge stages).  ``ops.local_sort``
  composes grid-tiled sorts with a pairwise merge tree for inputs larger
  than one tile.

Tiles are 2-D ``(rows, 128)`` — lane-dim 128 keeps every stage aligned to
the VPU registers; rows ≤ 8192 keeps a tile ≤ 4 MiB (f32) ≪ 16 MiB VMEM.
All kernels are validated against ``ref.py`` in interpret mode on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _log2(n: int) -> int:
    k = n.bit_length() - 1
    if 1 << k != n:
        raise ValueError(f"{n} is not a power of two")
    return k


def _compare_exchange(x: jax.Array, s: int, j: int) -> jax.Array:
    """One bitonic stage on flat ``x`` (N=2**k): block 2**(s+1), distance 2**j."""
    n = x.shape[0]
    d = 1 << j
    y = x.reshape(n // (2 * d), 2, d)
    a, b = y[:, 0, :], y[:, 1, :]
    q = jnp.arange(n // (2 * d), dtype=jnp.int32)
    asc = (((q >> (s - j)) & 1) == 0)[:, None]
    mn, mx = jnp.minimum(a, b), jnp.maximum(a, b)
    lo = jnp.where(asc, mn, mx)
    hi = jnp.where(asc, mx, mn)
    return jnp.stack([lo, hi], axis=1).reshape(n)


def _compare_exchange_pairs(k: jax.Array, v: jax.Array, s: int, j: int):
    """Stage moving payload ``v`` with its key ``k`` (swap-mask formulation)."""
    n = k.shape[0]
    d = 1 << j
    ky = k.reshape(n // (2 * d), 2, d)
    vy = v.reshape(n // (2 * d), 2, d)
    ka, kb = ky[:, 0, :], ky[:, 1, :]
    va, vb = vy[:, 0, :], vy[:, 1, :]
    q = jnp.arange(n // (2 * d), dtype=jnp.int32)
    asc = (((q >> (s - j)) & 1) == 0)[:, None]
    swap = jnp.where(asc, ka > kb, ka < kb)
    k_lo = jnp.where(swap, kb, ka)
    k_hi = jnp.where(swap, ka, kb)
    v_lo = jnp.where(swap, vb, va)
    v_hi = jnp.where(swap, va, vb)
    return (
        jnp.stack([k_lo, k_hi], axis=1).reshape(n),
        jnp.stack([v_lo, v_hi], axis=1).reshape(n),
    )


def _compare_exchange_tagged(k, t, v, s: int, j: int):
    """Stage ordering by ``(tag, key)`` lexicographically, payload follows.

    The tag is a validity bit (0 = real, 1 = pad): pad slots sort strictly
    after *every* real slot — even when a real key equals the dtype-max pad
    sentinel — so slicing ``[:n]`` can never trade a real payload for a
    pad's zero payload.
    """
    n = k.shape[0]
    d = 1 << j
    ky = k.reshape(n // (2 * d), 2, d)
    ty = t.reshape(n // (2 * d), 2, d)
    vy = v.reshape(n // (2 * d), 2, d)
    ka, kb = ky[:, 0, :], ky[:, 1, :]
    ta, tb = ty[:, 0, :], ty[:, 1, :]
    va, vb = vy[:, 0, :], vy[:, 1, :]
    q = jnp.arange(n // (2 * d), dtype=jnp.int32)
    asc = (((q >> (s - j)) & 1) == 0)[:, None]
    a_gt_b = (ta > tb) | ((ta == tb) & (ka > kb))
    a_lt_b = (ta < tb) | ((ta == tb) & (ka < kb))
    swap = jnp.where(asc, a_gt_b, a_lt_b)
    out = []
    for xa, xb in ((ka, kb), (ta, tb), (va, vb)):
        lo = jnp.where(swap, xb, xa)
        hi = jnp.where(swap, xa, xb)
        out.append(jnp.stack([lo, hi], axis=1).reshape(n))
    return tuple(out)


def _sort_network(x: jax.Array) -> jax.Array:
    kbits = _log2(x.shape[0])
    for s in range(kbits):
        for j in range(s, -1, -1):
            x = _compare_exchange(x, s, j)
    return x


def _merge_network(x: jax.Array) -> jax.Array:
    """Final merge phase only: x must already be bitonic (e.g. sorted↑ ++ sorted↓)."""
    kbits = _log2(x.shape[0])
    s = kbits - 1
    for j in range(s, -1, -1):
        x = _compare_exchange(x, s, j)
    return x


# ----------------------------------------------------------------- kernels
def bitonic_sort_kernel(x_ref, o_ref):
    n = x_ref.shape[0] * x_ref.shape[1]
    o_ref[...] = _sort_network(x_ref[...].reshape(n)).reshape(x_ref.shape)


def bitonic_sort_pairs_kernel(k_ref, v_ref, ok_ref, ov_ref):
    n = k_ref.shape[0] * k_ref.shape[1]
    keys, vals = k_ref[...].reshape(n), v_ref[...].reshape(n)
    kbits = _log2(n)
    for s in range(kbits):
        for j in range(s, -1, -1):
            keys, vals = _compare_exchange_pairs(keys, vals, s, j)
    ok_ref[...] = keys.reshape(k_ref.shape)
    ov_ref[...] = vals.reshape(v_ref.shape)


def bitonic_sort_pairs_tagged_kernel(k_ref, t_ref, v_ref, ok_ref, ov_ref):
    """Pairs sort on lexicographic ``(validity tag, key)`` — sentinel-safe."""
    n = k_ref.shape[0] * k_ref.shape[1]
    keys = k_ref[...].reshape(n)
    tags = t_ref[...].reshape(n)
    vals = v_ref[...].reshape(n)
    kbits = _log2(n)
    for s in range(kbits):
        for j in range(s, -1, -1):
            keys, tags, vals = _compare_exchange_tagged(keys, tags, vals, s, j)
    ok_ref[...] = keys.reshape(k_ref.shape)
    ov_ref[...] = vals.reshape(v_ref.shape)


def bitonic_merge_kernel(a_ref, b_ref, lo_ref, hi_ref):
    """Merge two sorted tiles a,b → (lo, hi) sorted halves of their union."""
    n = a_ref.shape[0] * a_ref.shape[1]
    a = a_ref[...].reshape(n)
    b = b_ref[...].reshape(n)[::-1]  # reversed: a ++ rev(b) is bitonic
    merged = _merge_network(jnp.concatenate([a, b]))
    lo_ref[...] = merged[:n].reshape(a_ref.shape)
    hi_ref[...] = merged[n:].reshape(a_ref.shape)


# ------------------------------------------------------------ pallas_call
def _tile_shape(n: int) -> tuple[int, int]:
    if n % LANES:
        raise ValueError(f"n={n} must be a multiple of {LANES}")
    return (n // LANES, LANES)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_tile(x: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Sort one power-of-two tile (flat) entirely in VMEM."""
    n = x.shape[0]
    shape = _tile_shape(n)
    x2 = x.reshape(shape)
    out = pl.pallas_call(
        bitonic_sort_kernel,
        out_shape=jax.ShapeDtypeStruct(shape, x.dtype),
        in_specs=[pl.BlockSpec(shape, lambda: (0, 0))],
        out_specs=pl.BlockSpec(shape, lambda: (0, 0)),
        interpret=interpret,
    )(x2)
    return out.reshape(n)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_pairs_tile(keys: jax.Array, vals: jax.Array, *, interpret: bool = False):
    n = keys.shape[0]
    shape = _tile_shape(n)
    ok, ov = pl.pallas_call(
        bitonic_sort_pairs_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(shape, keys.dtype),
            jax.ShapeDtypeStruct(shape, vals.dtype),
        ),
        in_specs=[pl.BlockSpec(shape, lambda: (0, 0))] * 2,
        out_specs=[pl.BlockSpec(shape, lambda: (0, 0))] * 2,
        interpret=interpret,
    )(keys.reshape(shape), vals.reshape(shape))
    return ok.reshape(n), ov.reshape(n)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_pairs_tile_tagged(
    keys: jax.Array, tags: jax.Array, vals: jax.Array, *, interpret: bool = False
):
    """Pairs sort with a validity tag (0 = real, 1 = pad) breaking key ties.

    ``tags`` may be traced (e.g. ``arange(n) >= n_valid``), so one compiled
    executable serves every valid length in a shape bucket.
    """
    n = keys.shape[0]
    shape = _tile_shape(n)
    ok, ov = pl.pallas_call(
        bitonic_sort_pairs_tagged_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(shape, keys.dtype),
            jax.ShapeDtypeStruct(shape, vals.dtype),
        ),
        in_specs=[pl.BlockSpec(shape, lambda: (0, 0))] * 3,
        out_specs=[pl.BlockSpec(shape, lambda: (0, 0))] * 2,
        interpret=interpret,
    )(keys.reshape(shape), tags.reshape(shape), vals.reshape(shape))
    return ok.reshape(n), ov.reshape(n)


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_tiles(a: jax.Array, b: jax.Array, *, interpret: bool = False):
    """Merge two sorted equal-length tiles → (lo, hi)."""
    n = a.shape[0]
    shape = _tile_shape(n)
    lo, hi = pl.pallas_call(
        bitonic_merge_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(shape, a.dtype),
            jax.ShapeDtypeStruct(shape, a.dtype),
        ),
        in_specs=[pl.BlockSpec(shape, lambda: (0, 0))] * 2,
        out_specs=[pl.BlockSpec(shape, lambda: (0, 0))] * 2,
        interpret=interpret,
    )(a.reshape(shape), b.reshape(shape))
    return lo.reshape(n), hi.reshape(n)
