"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_sort(x: jax.Array) -> jax.Array:
    return jnp.sort(x)


def ref_sort_pairs(keys: jax.Array, vals: jax.Array):
    """Stable sort of (key, payload) pairs by key."""
    order = jnp.argsort(keys, stable=True)
    return keys[order], vals[order]


def ref_merge(a: jax.Array, b: jax.Array):
    """Merge two sorted arrays → (lo, hi) sorted halves of the union."""
    m = jnp.sort(jnp.concatenate([a, b]))
    return m[: a.shape[0]], m[a.shape[0] :]


def ref_bucket_count_rank(ids: jax.Array, num_buckets: int):
    counts = jnp.zeros(num_buckets, jnp.int32).at[ids].add(1)
    onehot = jax.nn.one_hot(ids, num_buckets, dtype=jnp.int32)
    excl = jnp.cumsum(onehot, axis=0) - onehot
    ranks = jnp.take_along_axis(excl, ids[:, None].astype(jnp.int32), axis=1)[:, 0]
    return counts, ranks
