"""The paper's four input-array distributions (§5): random, sorted,
reverse-sorted, and 'local'.

'local' is interpreted as a *value-clustered* (gaussian) distribution —
the case where the paper's equal-width range partitioning collapses
(their local-distribution speedups stall at ~10%, §6.2): most values fall
inside a few value buckets, so a few processors receive almost everything.
The sampled-splitter (beyond-paper) method stays balanced on it, which
benchmarks demonstrate side by side.
"""

from __future__ import annotations

import numpy as np

DISTRIBUTIONS = ("random", "sorted", "reversed", "local")

# Beyond-paper: duplicate-heavy traffic (a handful of distinct values with a
# zipf-like mass).  Every splitter rule collapses on the dominant value —
# only capacity autotuning (DESIGN.md §4) survives it — so the engine tests
# and benchmarks include it alongside the paper's four.
ALL_DISTRIBUTIONS = DISTRIBUTIONS + ("dupes",)

# Paper sizes: 10..60 MB of int32 → 2.62M..15.73M elements.
PAPER_SIZES_MB = (10, 20, 30, 40, 50, 60)


def elements_for_mb(mb: int) -> int:
    return mb * (1 << 20) // 4


def key_space_max(dtype) -> int:
    """Largest generated key value for ``dtype``.

    Integer dtypes use their own representable max (capped at the int64
    max, the generation dtype) so "different integer array types" really
    exercises different key widths; float dtypes keep the paper's int32
    key space (every paper experiment sorts integer keys — float32 just
    stores them).
    """
    dt = np.dtype(dtype)
    if np.issubdtype(dt, np.integer):
        return int(min(np.iinfo(dt).max, np.iinfo(np.int64).max))
    return int(np.iinfo(np.int32).max)


def make_array(dist: str, n: int, seed: int = 0, dtype=np.int32) -> np.ndarray:
    """Generate one paper-grid input array, scaled to ``dtype``'s key space.

    For the default int32 this is bit-identical to the historical
    generator; narrower/wider integer dtypes draw from their own
    representable range so values never wrap through the final cast.
    """
    rng = np.random.default_rng(seed)
    dt = np.dtype(dtype)
    vmax = key_space_max(dt)
    if dist == "random":
        x = rng.integers(0, vmax, n, dtype=np.int64)
    elif dist == "sorted":
        x = np.sort(rng.integers(0, vmax, n, dtype=np.int64))
    elif dist == "reversed":
        x = np.sort(rng.integers(0, vmax, n, dtype=np.int64))[::-1]
    elif dist == "dupes":
        # 16 distinct values, zipf-weighted: the most frequent value carries
        # ~a third of the array, so one bucket holds ≫ n/P regardless of the
        # splitter rule.
        vals = rng.integers(0, vmax, 16, dtype=np.int64)
        w = 1.0 / np.arange(1, 17)
        x = rng.choice(vals, size=n, p=w / w.sum())
    elif dist == "local":
        # tight gaussian cluster in the middle of the key space + a thin
        # uniform tail so min/max span the full range (worst case for
        # equal-width splitters: the span is huge, the mass is narrow).
        # The cluster width scales with the key space; for very narrow
        # dtypes (int8) it degenerates toward the dupes class, which is the
        # honest physical limit of "local" on a 127-value space.
        center = vmax // 2
        sigma = max(1.0, 1e5 * (vmax / np.iinfo(np.int32).max))
        x = rng.normal(center, sigma, n).astype(np.int64)
        k = max(n // 1000, 2)
        idx = rng.integers(0, n, k)
        x[idx] = rng.integers(0, vmax, k, dtype=np.int64)
    else:
        raise ValueError(f"unknown distribution {dist!r}")
    return np.clip(x, 0, vmax).astype(dt)
