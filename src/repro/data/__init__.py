from repro.data.pipeline import SyntheticLMData
from repro.data.distributions import make_array

__all__ = ["SyntheticLMData", "make_array"]
