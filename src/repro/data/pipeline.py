"""Deterministic synthetic LM data pipeline.

Checkpointable by construction: batch ``i`` is a pure function of
(seed, i), so restoring a run at step N reproduces the exact token stream
— the data-pipeline state in a checkpoint is just the step counter.
Host-sharded: each process materialises only its slice of the global
batch (single-process on this container, but the slicing logic is the
multi-host one).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class SyntheticLMData:
    cfg: ModelConfig
    batch: int
    seq_len: int
    seed: int = 0
    step: int = 0  # checkpointable pipeline state

    def _tokens(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        # zipf-flavoured marginals ≈ natural-language token frequencies
        z = rng.zipf(1.3, size=(self.batch, self.seq_len + 1)).astype(np.int64)
        return (z % self.cfg.vocab_size).astype(np.int32)

    def next_batch(self) -> dict:
        t = self._tokens(self.step)
        self.step += 1
        batch = {"tokens": jnp.asarray(t[:, :-1]), "labels": jnp.asarray(t[:, 1:])}
        cfg = self.cfg
        if cfg.family == "encdec":
            rng = np.random.default_rng((self.seed, self.step, 7))
            batch["enc_frames"] = jnp.asarray(
                rng.normal(0, 1, (self.batch, cfg.encoder_seq_len, cfg.d_model)),
                dtype=cfg.dtype,
            )
        if cfg.family == "vlm":
            rng = np.random.default_rng((self.seed, self.step, 11))
            batch["vision_embeds"] = jnp.asarray(
                rng.normal(0, 1, (self.batch, cfg.vision_tokens, cfg.d_model)),
                dtype=cfg.dtype,
            )
            pos = np.broadcast_to(
                np.arange(self.seq_len), (3, self.batch, self.seq_len)
            )
            batch["positions_thw"] = jnp.asarray(pos.astype(np.int32))
        return batch

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def restore(self, state: dict):
        self.seed, self.step = int(state["seed"]), int(state["step"])
