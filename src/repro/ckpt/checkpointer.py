"""Checkpointing: atomic, async, elastic-reshard on restore.

Layout:  <dir>/step_<N>/manifest.json + <leaf-path>.npy per pytree leaf.
Writes go to ``step_<N>.tmp`` then ``os.rename`` — a crashed save can
never be mistaken for a complete checkpoint (restart-safety).  Saves can
run on a background thread (``async_save``); ``wait()`` joins before the
next save or exit.

Restore is **elastic**: leaves are stored as full logical arrays, so a
checkpoint written on one mesh restores onto any other mesh/sharding —
pass ``sharding_tree`` and each leaf is ``jax.device_put`` with its new
spec.  This is the mechanism behind pod-loss recovery: rebuild a smaller
mesh, restore, continue (see repro.runtime.elastic).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict, skeleton):
    if isinstance(skeleton, dict):
        return {k: _unflatten(
            {p[len(k) + 1 :]: v for p, v in flat.items() if p.split("/")[0] == k},
            skeleton[k],
        ) for k in skeleton}
    if isinstance(skeleton, (list, tuple)):
        vals = [
            _unflatten(
                {p[len(str(i)) + 1 :]: v for p, v in flat.items() if p.split("/")[0] == str(i)},
                s,
            )
            for i, s in enumerate(skeleton)
        ]
        return type(skeleton)(vals)
    return flat[""]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, extra: dict | None = None, async_save=False):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            flat = _flatten(host_tree)
            manifest = {"step": step, "leaves": {}, "extra": extra or {}}
            for path, arr in flat.items():
                fname = path.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"][path] = fname
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        if async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, skeleton, sharding_tree=None):
        """Load a checkpoint; optionally placing leaves with new shardings
        (elastic re-shard).  Returns (tree, extra)."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {
            path: np.load(os.path.join(d, fname))
            for path, fname in manifest["leaves"].items()
        }
        tree = _unflatten(flat, skeleton)
        if sharding_tree is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else jax.device_put(x),
                tree,
                sharding_tree,
            )
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree, manifest.get("extra", {})
