"""Next-token cross-entropy with z-loss + MoE aux."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lm_loss(logits: jax.Array, labels: jax.Array, *, z_loss: float = 1e-4):
    """logits (B,S,V) vs labels (B,S).  Returns (loss, metrics)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    z = z_loss * jnp.square(lse)
    loss = jnp.mean(nll + z)
    return loss, {
        "ce": jnp.mean(nll),
        "z_loss": jnp.mean(z),
        "accuracy": jnp.mean(jnp.argmax(lf, -1) == labels),
    }
