from repro.train.loss import lm_loss
from repro.train.train_step import make_train_step, init_train_state
from repro.train.trainer import Trainer

__all__ = ["lm_loss", "make_train_step", "init_train_state", "Trainer"]
