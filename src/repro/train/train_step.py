"""The jit'd training step: loss → grad → (optional compression) → AdamW.

Buffer donation on (params, opt_state) keeps peak memory at
params + grads + states (not 2×params); remat inside the model bounds
activation memory; the LR schedule runs on the traced step so one compiled
step serves the whole run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models.common import AxisRules, NO_SHARD
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import compress_grads, init_error_fb
from repro.optim.schedules import cosine_warmup
from repro.train.loss import lm_loss


def init_train_state(key, cfg: ModelConfig, run: RunConfig, model_api):
    params = model_api.init(key, cfg)
    opt = adamw_init(params)
    if run.master_weights:
        # §Perf lever: f32 master lives in the optimizer; live params are
        # bf16, halving FSDP all-gather and DP grad-reduce bytes.
        opt["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
    state = {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}
    if run.grad_compression == "int8":
        state["error_fb"] = init_error_fb(params)
    return state


def make_train_step(cfg: ModelConfig, run: RunConfig, model_api,
                    rules: AxisRules = NO_SHARD, grad_specs=None):
    """``grad_specs``: optional PartitionSpec tree for gradients — a
    with_sharding_constraint right after the VJP lets the partitioner use
    reduce-scatter into the (FSDP-sharded) accumulation buffer instead of a
    full all-reduce (§Perf lever 'gradrs')."""
    opt_cfg = AdamWConfig(weight_decay=run.weight_decay, grad_clip=run.grad_clip)

    def loss_fn(params, batch):
        logits, aux = model_api.forward(params, batch, cfg, rules)
        loss, metrics = lm_loss(logits, batch["labels"])
        return loss + aux, (metrics, aux)

    # microbatch split axis per input key ((3,B,S) positions are axis 1)
    _MB_AXIS = {"positions_thw": 1}

    def _constrain(g):
        if grad_specs is None:
            return g
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s)
            if s is not None else x,
            g, grad_specs,
            is_leaf=lambda s: s is None
            or isinstance(s, jax.sharding.PartitionSpec),
        )

    def _grads(params, batch):
        A = run.grad_accum
        if A <= 1:
            (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            return (l, aux), _constrain(g)

        def split(k, x):
            ax = _MB_AXIS.get(k, 0)
            b = x.shape[ax]
            new = x.shape[:ax] + (A, b // A) + x.shape[ax + 1 :]
            return jnp.moveaxis(x.reshape(new), ax, 0)

        mbs = {k: split(k, v) for k, v in batch.items()}

        def body(acc, mb):
            (loss, (metrics, aux)), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb
            )
            g = _constrain(g)
            g32 = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc[0], g)
            return (g32, acc[1] + loss, acc[2] + aux,
                    jax.tree.map(lambda a, b: a + b, acc[3], metrics)), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        zero_m = {"ce": 0.0, "z_loss": 0.0, "accuracy": 0.0}
        zero_m = jax.tree.map(jnp.float32, zero_m)
        from repro.models.common import maybe_scan

        (g, loss, aux, metrics), _ = maybe_scan(
            body, (zero_g, jnp.float32(0), jnp.float32(0), zero_m), mbs,
            not run.grad_accum_unroll,
        )
        inv = 1.0 / A
        return (loss * inv, (jax.tree.map(lambda m: m * inv, metrics), aux * inv)), \
            jax.tree.map(lambda x: x * inv, g)

    def train_step(state, batch):
        (loss, (metrics, aux)), grads = _grads(state["params"], batch)
        if run.grad_compression == "int8":
            grads, new_fb = compress_grads(grads, state["error_fb"])
        lr = cosine_warmup(
            state["step"], peak_lr=run.learning_rate, warmup=run.warmup_steps,
            total=run.total_steps,
        )
        if run.master_weights:
            inner = {k: state["opt"][k] for k in ("m", "v", "count")}
            new_master, new_opt, opt_metrics = adamw_update(
                state["opt"]["master"], grads, inner, lr, opt_cfg
            )
            new_opt["master"] = new_master
            new_params = jax.tree.map(
                lambda m, p: m.astype(p.dtype), new_master, state["params"]
            )
        else:
            new_params, new_opt, opt_metrics = adamw_update(
                state["params"], grads, state["opt"], lr, opt_cfg
            )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        if run.grad_compression == "int8":
            new_state["error_fb"] = new_fb
        out_metrics = {"loss": loss, "aux": aux, "lr": lr, **metrics, **opt_metrics}
        return new_state, out_metrics

    return train_step


def jit_train_step(train_step, mesh=None, state_specs=None, batch_specs=None):
    """jit with donation (and shardings when a mesh is given)."""
    if mesh is None:
        return jax.jit(train_step, donate_argnums=(0,))
    from jax.sharding import NamedSharding

    to_sharding = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec),
    )
    return jax.jit(
        train_step,
        donate_argnums=(0,),
        in_shardings=(to_sharding(state_specs), to_sharding(batch_specs)),
        out_shardings=(to_sharding(state_specs), None),
    )
