"""Training loop with the fleet-survival features:

* **checkpoint/restart**: periodic async atomic saves (params, optimizer,
  step, data-pipeline state); on construction the trainer auto-resumes
  from the newest complete checkpoint.
* **fault tolerance**: a step that raises (device loss is injectable via
  ``fault_hook`` in tests) triggers restore-from-last-checkpoint and
  replay; repeated failures escalate.
* **straggler mitigation**: per-step wall times feed an EWMA watchdog; a
  step slower than ``straggler_factor``× the EWMA is logged and counted
  (on a real fleet this signal feeds the re-scheduling/elastic layer —
  here it drives the metrics surfaced to the caller).  The *algorithmic*
  straggler story for the paper's workload (bucket imbalance) lives in
  the sort layer's sampled splitters.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.ckpt.checkpointer import Checkpointer
from repro.configs.base import ModelConfig, RunConfig
from repro.data.pipeline import SyntheticLMData
from repro.train.train_step import init_train_state, jit_train_step, make_train_step


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        run: RunConfig,
        model_api,
        *,
        rules=None,
        mesh=None,
        fault_hook=None,
        straggler_factor: float = 3.0,
        sync_checkpoints: bool = False,  # deterministic saves (tests)
    ):
        from repro.models.common import NO_SHARD

        self.cfg, self.run, self.api = cfg, run, model_api
        self.rules = rules or NO_SHARD
        self.mesh = mesh
        self.fault_hook = fault_hook
        self.straggler_factor = straggler_factor
        self.sync_checkpoints = sync_checkpoints
        self.ckpt = Checkpointer(run.checkpoint_dir, keep=run.keep_checkpoints)
        self.data = SyntheticLMData(
            cfg, run.shape.global_batch, run.shape.seq_len, seed=run.seed
        )
        key = jax.random.PRNGKey(run.seed)
        self.state = init_train_state(key, cfg, run, model_api)
        self.step_fn = jit_train_step(make_train_step(cfg, run, model_api, self.rules))
        self._ewma = None
        self.metrics_log: list[dict] = []
        self.straggler_steps: list[int] = []
        self.restarts = 0
        self._maybe_resume()

    # ------------------------------------------------------------- lifecycle
    def _maybe_resume(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            return
        skeleton = jax.tree.map(lambda x: None, self.state)
        self.state, extra = self.ckpt.restore(latest, skeleton)
        if "data" in extra:
            self.data.restore(extra["data"])

    def _save(self, step: int):
        self.ckpt.save(
            step, self.state, extra={"data": self.data.state()},
            async_save=not self.sync_checkpoints,
        )

    # ------------------------------------------------------------------ run
    def run_steps(self, n: int) -> list[dict]:
        done = 0
        while done < n:
            step_no = int(self.state["step"])
            batch = self.data.next_batch()
            t0 = time.perf_counter()
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step_no)
                self.state, metrics = self.step_fn(self.state, batch)
                metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
            except _Recoverable as e:  # injected / device failure
                self.restarts += 1
                self._recover()
                continue
            dt = time.perf_counter() - t0
            metrics["step"] = step_no
            metrics["wall_s"] = dt
            self._watch_straggler(step_no, dt)
            self.metrics_log.append(metrics)
            done += 1
            if self.run.checkpoint_every and (step_no + 1) % self.run.checkpoint_every == 0:
                self._save(step_no + 1)
        self.ckpt.wait()
        return self.metrics_log

    def _watch_straggler(self, step: int, dt: float):
        if self._ewma is None:
            self._ewma = dt
        elif dt > self.straggler_factor * self._ewma:
            self.straggler_steps.append(step)
        self._ewma = 0.9 * self._ewma + 0.1 * dt if self._ewma else dt

    def _recover(self):
        """Restore from the newest checkpoint and replay the data stream."""
        latest = self.ckpt.latest_step()
        if latest is None:
            # no checkpoint yet: reinitialise (fresh start is the only replay)
            key = jax.random.PRNGKey(self.run.seed)
            self.state = init_train_state(key, self.cfg, self.run, self.api)
            self.data.step = 0
            return
        skeleton = jax.tree.map(lambda x: None, self.state)
        self.state, extra = self.ckpt.restore(latest, skeleton)
        if "data" in extra:
            self.data.restore(extra["data"])


class _Recoverable(Exception):
    """Raised by fault hooks to simulate a recoverable fleet failure."""


RecoverableFailure = _Recoverable
