"""jax version-compatibility shims.

The framework targets the modern jax API (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``); CI pins and
hermetic containers may carry jax 0.4.x, where shard_map still lives in
``jax.experimental`` with ``check_rep`` and meshes take no axis types.
Every call site routes through these two wrappers so the version split
lives in exactly one file.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = {"check_vma": False}
else:  # pragma: no cover - jax<=0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = {"check_rep": False}


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any jax version."""
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **_CHECK_KW
    )


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` on modern jax; on 0.4.x a ``Mesh`` is itself a context
    manager that pushes the thread-local physical mesh.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def get_ambient_mesh():
    """The mesh installed by :func:`set_mesh`, or ``None`` outside one."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        m = jax.sharding.get_abstract_mesh()
        return m if (m is not None and m.shape) else None
    from jax.interpreters import pxla  # pragma: no cover - jax<=0.4.x

    m = pxla.thread_resources.env.physical_mesh
    return None if m.empty else m


def axis_size(axis_name):
    """``jax.lax.axis_size`` (jax>=0.6); 0.4.x spells it psum(1, axis)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)  # pragma: no cover - jax<=0.4.x


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on any jax version
    (0.4.x returns a list with one dict per computation)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with Auto axis types where the version wants them."""
    kw = {} if devices is None else {"devices": devices}
    if hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = tuple(
            jax.sharding.AxisType.Auto for _ in axis_names
        )
    return jax.make_mesh(axis_shapes, axis_names, **kw)
