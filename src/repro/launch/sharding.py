"""Sharding-spec assembly for the dry-run and real launches.

Everything here operates on *logical* PartitionSpecs (axis names) plus the
concrete mesh, producing sanitized NamedShardings:

* ``sanitize_specs``: drop mesh axes that don't divide the corresponding
  array dim (e.g. whisper's vocab 51865 on a 16-way tensor axis, or
  qwen1.5-32b's 40 heads).  jit in/out shardings must divide evenly;
  the dropped axes simply mean that tensor is replicated on that axis —
  correct, just less sharded (the roofline section reports the cost).
* per-(arch × shape) ``AxisRules``: batch axes, FSDP, TP, and the special
  cases — SP (sequence sharding) for head counts indivisible by TP, and
  ``kv_seq`` sharding for the batch=1 ``long_500k`` decode cache.
"""

from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.common import AxisRules


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def rules_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> AxisRules:
    sizes = mesh_axis_sizes(mesh)
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    tp = sizes.get("model", 1)
    heads = "_default"  # resolves to the tensor axis
    seq = None
    # SP fallback: if H or KV heads don't divide the TP axis, shard the
    # sequence dim of activations instead (context parallelism).
    if cfg.num_heads % tp or (cfg.num_kv_heads and cfg.num_kv_heads % tp):
        heads = None
        if shape.seq_len % tp == 0 and shape.kind != "decode":
            seq = "model"
    kv_seq = None
    if shape.kind in ("decode", "prefill"):
        # KV heads that don't divide TP would replicate the cache across the
        # model axis — shard the cache's seq dim there instead.
        if cfg.num_kv_heads and cfg.num_kv_heads % tp:
            kv_seq = "model"
    if shape.kind == "decode":
        # global batch must cover the batch axes; if not, shard the cache's
        # sequence dim over the leftover axes (long_500k: batch=1).
        bsz = int(np.prod([sizes[a] for a in batch_axes])) if batch_axes else 1
        if shape.global_batch % bsz or shape.global_batch < bsz:
            batch_axes = ()
            kv_seq = ("data", "model") if cfg.num_kv_heads % tp else "data"
    return AxisRules(
        batch=batch_axes or None,
        fsdp="data",
        tensor="model",
        heads=heads,
        seq=seq,
        kv_seq=kv_seq,
    )


# ---------------------------------------------------------------- sanitize
def _shape_tree(tree):
    return jax.tree.map(lambda x: tuple(x.shape), tree)


def sanitize_specs(spec_tree, shaped_tree, mesh: Mesh):
    """Drop spec axes that don't evenly divide the array dims."""
    sizes = mesh_axis_sizes(mesh)

    def axis_size(entry) -> int:
        if entry is None:
            return 1
        if isinstance(entry, (tuple, list)):
            n = 1
            for e in entry:
                n *= sizes.get(e, 1)
            return n
        return sizes.get(entry, 1)

    def fix(spec, arr):
        if not isinstance(spec, P):
            return spec
        shape = arr.shape if hasattr(arr, "shape") else tuple(arr)
        entries = tuple(spec) + (None,) * (len(shape) - len(spec))
        out = []
        for dim, entry in zip(shape, entries):
            out.append(entry if entry and dim % axis_size(entry) == 0 else None)
        return P(*out)

    return jax.tree.map(
        fix, spec_tree, shaped_tree, is_leaf=lambda s: isinstance(s, P)
    )


def named(tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        tree,
        is_leaf=lambda s: isinstance(s, P),
    )


# --------------------------------------------------------------- batch spec
def batch_specs(cfg: ModelConfig, shape: ShapeConfig, rules: AxisRules) -> dict:
    b = rules.batch
    specs = {"tokens": P(b, None)}
    if shape.kind == "train":
        specs["labels"] = P(b, None)
    if cfg.family == "encdec":
        specs["enc_frames"] = P(b, None, None)
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["vision_embeds"] = P(b, None, None)
        specs["positions_thw"] = P(None, b, None)
    return specs


# --------------------------------------------------------------- cache spec
def cache_specs(cfg: ModelConfig, rules: AxisRules, cache_shapes) -> dict:
    """PartitionSpec tree matching the model's cache pytree.

    GQA KV: (L, B, S, KV, hd) → (None, batch, kv_seq, heads, None)
    MLA latent: c (L,B,S,r), kr (L,B,S,dr) → (None, batch, kv_seq, None)
    SSM: conv (L,B,W,C) → (None, batch, None, tensor);
         ssm (L,B,nh,hd,ds) → (None, batch, tensor, None, None)
    hybrid adds shared (periods, B, S, KV, hd).
    """
    r = rules

    def kv5(_):
        return r.spec(None, "batch", "kv_seq", "heads", None)

    if cfg.family == "ssm" or cfg.is_hybrid:
        specs = {
            "layers": {
                "conv": r.spec(None, "batch", None, "tensor"),
                "ssm": r.spec(None, "batch", "tensor", None, None),
            }
        }
        if cfg.is_hybrid:
            specs["shared"] = (kv5(None), kv5(None))
        return specs
    if cfg.mla.kv_lora_rank:
        return {
            "layers": {
                "c": r.spec(None, "batch", "kv_seq", None),
                "kr": r.spec(None, "batch", "kv_seq", None),
            }
        }
    if cfg.family == "encdec":
        return {"self": (kv5(None), kv5(None)), "cross": (kv5(None), kv5(None))}
    if cfg.decode_window_cache:
        # ring cache: (L, B, ring, KV, hd) ×2 + (L, ring) positions
        return {"layers": (kv5(None), kv5(None), P(None, None))}
    return {"layers": (kv5(None), kv5(None))}
