"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``.

Builds the engine (length-sorted batch formation via the bitonic pair-sort
kernel), prefills a batch of synthetic prompts and decodes greedily.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import registry
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(registry.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, smoke=args.smoke)
    api = registry.get_model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, api, max_len=256)
    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size, int(rng.integers(4, 48))).astype(np.int32),
                max_new_tokens=args.new_tokens)
        for i in range(args.requests)
    ]
    out = eng.generate(reqs)
    for rid, toks in sorted(out.items()):
        print(f"request {rid}: {len(toks)} tokens -> {toks[:8]}...")


if __name__ == "__main__":
    main()
