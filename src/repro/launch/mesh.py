"""Production meshes.  A FUNCTION (not a module constant) so importing
this module never touches jax device state."""

from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16)=256 chips single pod; (2,16,16)=512 chips across 2 pods.

    The ``pod`` axis is the OTIS "optical" tier of the paper's topology:
    every schedule in this framework is arranged to cross it once
    (hierarchical dispatch, hierarchical psum, two-level sort exchange).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_smoke_mesh(devices=None):
    """Whatever devices exist, as a 1-D 'data' mesh (CI / laptop)."""
    devices = devices if devices is not None else jax.devices()
    import numpy as np
    from jax.sharding import Mesh

    return Mesh(np.array(devices), ("data",))
