"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production mesh, prove it fits, extract calibrated roofline terms.

MUST be the very first two lines — jax locks the device count on first
init, and only this entrypoint may see 512 devices:
"""
import os  # noqa: E402

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.configs.base import SHAPES, ModelConfig, RunConfig, ShapeConfig  # noqa: E402
from repro.launch import sharding as SH  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.roofline.analysis import (  # noqa: E402
    collective_bytes,
    model_flops_for,
    roofline_from_compiled,
)
from repro.roofline.hw import V5E  # noqa: E402

# Microbatches per train step, sized so per-device activation memory
# (layers × tokens/dev × d_model × 2B under per-layer remat) stays well
# inside the 16 GB v5e HBM.  Effective value is min(this, B/batch_shards).
GRAD_ACCUM = {
    "whisper-tiny": 1,
    "mixtral-8x22b": 16,
    "deepseek-v2-lite-16b": 4,
    "minitron-4b": 8,
    "qwen1.5-32b": 16,
    "qwen1.5-110b": 16,
    "gemma3-4b": 8,
    "mamba2-370m": 2,
    "qwen2-vl-7b": 8,
    "zamba2-2.7b": 8,
}

# =============================================================== lowering
def _layer_period(cfg: ModelConfig) -> int:
    if cfg.is_hybrid:
        return cfg.hybrid_period
    if cfg.window_pattern:
        return len(cfg.window_pattern)
    return 1


def _scaled_cfg(cfg: ModelConfig, n_layers: int, scan: bool) -> ModelConfig:
    kw = {"num_layers": n_layers, "scan_layers": scan}
    if cfg.family == "encdec":
        kw["encoder_layers"] = max(
            1, cfg.encoder_layers * n_layers // max(cfg.num_layers, 1)
        )
    return cfg.replace(**kw)


def build_lowered(cfg, shape, mesh, run, *, cache_len=None):
    """Lower one computation (train/prefill/decode) on `mesh`.  Returns
    (lowered, rules)."""
    rules = SH.rules_for(cfg, shape, mesh)
    model_api = registry.get_model_api(cfg)
    in_specs = registry.input_specs(cfg, shape)
    bspecs = SH.sanitize_specs(SH.batch_specs(cfg, shape, rules), in_specs, mesh)
    tp = SH.mesh_axis_sizes(mesh).get("model", 1)
    key = jax.random.PRNGKey(0)
    pspecs_l = model_api.param_specs(cfg, rules, tp)
    params_shape = jax.eval_shape(lambda: model_api.init(key, cfg))
    pspecs = SH.sanitize_specs(pspecs_l, params_shape, mesh)

    with compat.set_mesh(mesh):
        if shape.kind == "train":
            from repro.train.train_step import init_train_state, make_train_step

            state_shape = jax.eval_shape(
                lambda: init_train_state(key, cfg, run, model_api)
            )
            opt_specs = {"m": pspecs, "v": pspecs, "count": P()}
            if run.master_weights:
                opt_specs["master"] = pspecs
            sspecs = {"params": pspecs, "opt": opt_specs, "step": P()}
            if run.grad_compression == "int8":
                sspecs["error_fb"] = pspecs
            gspecs = pspecs if getattr(run, "_grad_specs_flag", False) else None
            step = make_train_step(cfg, run, model_api, rules, grad_specs=gspecs)
            jitted = jax.jit(
                step,
                in_shardings=(SH.named(sspecs, mesh), SH.named(bspecs, mesh)),
                out_shardings=(SH.named(sspecs, mesh), None),
                donate_argnums=(0,),
            )
            return jitted.lower(state_shape, in_specs), rules
        cache_len = cache_len or shape.seq_len + 16
        cache_shape = jax.eval_shape(
            lambda: model_api.init_cache(cfg, shape.global_batch, cache_len)
        )
        cspecs = SH.sanitize_specs(
            SH.cache_specs(cfg, rules, cache_shape), cache_shape, mesh
        )
        if shape.kind == "prefill":
            fn = lambda p, b, c: model_api.prefill(p, b, cfg, rules, c)
            jitted = jax.jit(
                fn,
                in_shardings=(
                    SH.named(pspecs, mesh),
                    SH.named(bspecs, mesh),
                    SH.named(cspecs, mesh),
                ),
                out_shardings=(None, SH.named(cspecs, mesh)),
                donate_argnums=(2,),
            )
            return jitted.lower(params_shape, in_specs, cache_shape), rules
        fn = lambda p, t, c, pos: model_api.decode_step(p, t, cfg, rules, c, pos)
        jitted = jax.jit(
            fn,
            in_shardings=(
                SH.named(pspecs, mesh),
                SH.named(bspecs["tokens"], mesh),
                SH.named(cspecs, mesh),
                None,
            ),
            out_shardings=(None, SH.named(cspecs, mesh)),
            donate_argnums=(2,),
        )
        return (
            jitted.lower(
                params_shape, in_specs["tokens"], cache_shape,
                jax.ShapeDtypeStruct((), jnp.int32),
            ),
            rules,
        )


# ============================================================ calibration
def _measure(cfg, shape, mesh, run, *, pod_block):
    """Compile a (small) variant and pull raw per-device cost numbers.

    CPU-upcast fix: when params are intended bf16 (master_weights), f32
    weight-shaped collectives are counted at half width — see
    roofline.analysis.collective_bytes."""
    lowered, _ = build_lowered(cfg, shape, mesh, run)
    compiled = lowered.compile()
    ca = compat.cost_analysis(compiled)
    halve = None
    if run.master_weights:
        from repro.roofline.analysis import param_shape_set

        api = registry.get_model_api(cfg)
        halve = param_shape_set(
            jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), cfg))
        )
    coll = collective_bytes(
        compiled.as_text(), num_devices=mesh.devices.size, pod_block=pod_block,
        halve_param_shapes=halve,
    )
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_intra": float(coll["intra_pod"]),
        "coll_inter": float(coll["inter_pod"]),
    }


def _combine(base, per_layer, n_extra, mult=1.0):
    return {
        k: max(0.0, mult * (base[k] + n_extra * per_layer[k])) for k in base
    }


def calibrated_costs(arch, cfg, shape, mesh, *, a_eff, pod_block, run_kw=None):
    """True per-step per-device costs via small UNROLLED lowers.

    XLA cost_analysis counts a while-loop body once, so the full-config
    numbers undercount by the layer count (and microbatch count).  We
    compile L1- and L2-layer unrolled variants (and an A=2 unrolled
    microbatch variant for train) and reconstruct:

        per_layer = (X(L2) − X(L1)) / (L2 − L1)
        train:  per_step = 2·X(L1,A1) − X(L1,A2);  per_mb = X(L1,A2) − X(L1,A1)
                total = per_step + A·(per_mb + (L−L1)·per_layer)
        serve:  total = X(L1) + (L − L1)·per_layer
    """
    period = _layer_period(cfg)
    L1, L2 = period, 2 * period
    # fractional period units so non-multiple depths (gemma3: 34 = 5×6+4)
    # extrapolate exactly by layer count
    extra_units = (cfg.num_layers - L1) / period
    c1 = _scaled_cfg(cfg, L1, scan=False)
    c2 = _scaled_cfg(cfg, L2, scan=False)
    if shape.kind == "train":
        mb = shape.global_batch // a_eff
        sh1 = dataclasses.replace(shape, global_batch=mb)
        sh2 = dataclasses.replace(shape, global_batch=2 * mb)
        run_kw = dict(run_kw or {})
        gflag = run_kw.pop("_grad_specs", False)
        run1 = RunConfig(model=c1, shape=sh1, grad_accum=1, **run_kw)
        runA = RunConfig(model=c1, shape=sh2, grad_accum=2, grad_accum_unroll=True,
                         **run_kw)
        for r_ in (run1, runA):
            object.__setattr__(r_, "_grad_specs_flag", gflag)
        x1 = _measure(c1, sh1, mesh, run1, pod_block=pod_block)
        run2 = RunConfig(model=c2, shape=sh1, grad_accum=1, **run_kw)
        object.__setattr__(run2, "_grad_specs_flag", gflag)
        x2 = _measure(c2, sh1, mesh, run2, pod_block=pod_block)
        xa = _measure(c1, sh2, mesh, runA, pod_block=pod_block)
        per_layer = {k: (x2[k] - x1[k]) / (L2 - L1) * period for k in x1}
        per_step = {k: max(0.0, 2 * x1[k] - xa[k]) for k in x1}
        per_mb = {k: max(0.0, xa[k] - x1[k]) for k in x1}
        total = {
            k: per_step[k]
            + a_eff * (per_mb[k] + extra_units * per_layer[k])
            for k in x1
        }
        return total, {"L1": L1, "L2": L2, "a_eff": a_eff, "x1": x1, "x2": x2, "xa": xa}
    run_kw = dict(run_kw or {})
    run_kw.pop("_grad_specs", None)
    run1 = RunConfig(model=c1, shape=shape, **run_kw)
    x1 = _measure(c1, shape, mesh, run1, pod_block=pod_block)
    x2 = _measure(c2, shape, mesh, RunConfig(model=c2, shape=shape, **run_kw),
                  pod_block=pod_block)
    per_layer = {k: (x2[k] - x1[k]) / (L2 - L1) * period for k in x1}
    total = _combine(x1, per_layer, extra_units)
    return total, {"L1": L1, "L2": L2, "x1": x1, "x2": x2}


# ================================================================= orchestration
def _lv_moefix(cfg, run_kw):
    return cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch_sharded=True)), run_kw


def _lv_moesm(cfg, run_kw):
    return cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="shard_map")), run_kw


LEVERS = {
    # §Perf levers: name → fn(cfg, run_kw) -> (cfg', run_kw')
    "bf16mm": lambda c, r: (c.replace(attn_matmul_bf16=True), r),
    "inscan": lambda c, r: (c.replace(prefill_inscan_cache=True), r),
    "master": lambda c, r: (c, {**r, "master_weights": True}),
    "chunk4k": lambda c, r: (c.replace(attn_chunk=4096), r),
    "moefix": _lv_moefix,
    "moesm": _lv_moesm,
    "wincache": lambda c, r: (c.replace(decode_window_cache=True), r),
    "gradrs": lambda c, r: (c, {**r, "_grad_specs": True}),
    "accum8": lambda c, r: (c, {**r, "_grad_accum": 8}),
    # revert production defaults to the paper-faithful baseline
    "paperbase": lambda c, r: (
        c.replace(
            decode_window_cache=False,
            moe=dataclasses.replace(c.moe, dispatch="sorted", dispatch_sharded=False)
            if c.moe.num_experts else c.moe,
        ),
        r,
    ),
}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool, calibrate=True,
               levers: tuple = ()):
    cfg = registry.get_config(arch)
    run_kw = {}
    for lv in levers:
        cfg, run_kw = LEVERS[lv](cfg, run_kw)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = mesh.devices.size
    sizes = SH.mesh_axis_sizes(mesh)
    batch_shards = int(np.prod([sizes.get(a, 1) for a in ("pod", "data")]))
    a_eff = 1
    if shape.kind == "train":
        a_cap = run_kw.pop("_grad_accum", GRAD_ACCUM.get(arch, 1))
        a_eff = max(1, min(a_cap, shape.global_batch // batch_shards))
    else:
        run_kw.pop("_grad_accum", None)
    grad_specs_flag = run_kw.get("_grad_specs", False)
    run = RunConfig(
        model=cfg, shape=shape, grad_accum=a_eff,
        **{k: v for k, v in run_kw.items() if k != "_grad_specs"},
    )
    object.__setattr__(run, "_grad_specs_flag", grad_specs_flag)
    pod_block = ndev // 2 if multi_pod else None

    # ---- full-config compile: proves sharding coherence + memory fit
    t0 = time.time()
    lowered, rules = build_lowered(cfg, shape, mesh, run)
    t_lower = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1
    mem = compiled.memory_analysis()
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "devices": ndev,
        "grad_accum": a_eff,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "rules": {
            "batch": rules.batch,
            "heads": None if rules.heads is None else "tp",
            "seq": rules.seq,
            "kv_seq": rules.kv_seq,
        },
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "total_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "raw_roofline_scanbody_once": roofline_from_compiled(
            compiled, num_devices=ndev, pod_block=pod_block
        ),
    }

    # ---- calibrated roofline (true per-step costs)
    if calibrate:
        total, detail = calibrated_costs(
            arch, cfg, shape, mesh, a_eff=a_eff, pod_block=pod_block,
            run_kw=run_kw,
        )
        hw = V5E
        t_compute = total["flops"] / hw.peak_bf16_flops
        t_memory = total["bytes"] / hw.hbm_bw
        t_coll = total["coll_intra"] / hw.ici_bw + total["coll_inter"] / hw.inter_pod_bw
        terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
        mf = model_flops_for(cfg, shape)
        bound = max(terms.values())
        rec["roofline"] = {
            "flops_per_device": total["flops"],
            "bytes_per_device": total["bytes"],
            "coll_intra_bytes": total["coll_intra"],
            "coll_inter_bytes": total["coll_inter"],
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "dominant": max(terms, key=terms.get),
            "bound_time_s": bound,
            "model_flops": mf,
            "useful_flops_ratio": mf / (total["flops"] * ndev)
            if total["flops"]
            else 0.0,
            "roofline_fraction": (mf / ndev / hw.peak_bf16_flops) / bound
            if bound > 0
            else 0.0,
            "calibration": detail,
        }
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--levers", default="", help="comma list: bf16mm,inscan,master,chunk4k")
    ap.add_argument("--tag", default="", help="suffix for output filenames")
    args = ap.parse_args()
    levers = tuple(x for x in args.levers.split(",") if x)

    archs = [args.arch] if args.arch else list(registry.ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    n_ok, failures = 0, []
    for arch in archs:
        for shape_name in shapes:
            ok, why = registry.cell_supported(arch, shape_name)
            if not ok:
                print(f"SKIP  {arch} × {shape_name}: {why}")
                continue
            for multi in meshes:
                tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"CACHED {tag}")
                    n_ok += 1
                    continue
                print(f"RUN   {tag} ...", flush=True)
                try:
                    rec = lower_cell(
                        arch, shape_name, multi_pod=multi,
                        calibrate=not args.no_calibrate, levers=levers,
                    )
                    if levers:
                        rec["levers"] = list(levers)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    line = (
                        f"  OK compile={rec['compile_s']}s "
                        f"hbm={rec['memory_analysis']['total_bytes']/1e9:.2f}GB/dev"
                    )
                    if "roofline" in rec:
                        r = rec["roofline"]
                        line += (
                            f" dominant={r['dominant']}"
                            f" compute={r['t_compute_s']:.2e}s"
                            f" mem={r['t_memory_s']:.2e}s"
                            f" coll={r['t_collective_s']:.2e}s"
                            f" roofline_frac={r['roofline_fraction']:.3f}"
                        )
                    print(line, flush=True)
                    n_ok += 1
                except Exception as e:
                    failures.append((tag, repr(e)))
                    with open(os.path.join(args.out, tag + ".FAIL"), "w") as f:
                        f.write(traceback.format_exc())
                    print(f"  FAIL {e!r}", flush=True)
    print(f"\n{n_ok} ok, {len(failures)} failed")
    for tag, err in failures:
        print("  FAIL", tag, err[:160])
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
