"""Diagnostic: compile a small unrolled variant of a cell and print the
largest collective ops and buffer-traffic sources from the optimized HLO.

    PYTHONPATH=src python -m repro.launch.diag --arch X --shape Y [--levers ...]
"""
import os  # noqa: E402

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import collections  # noqa: E402
import dataclasses  # noqa: E402
import re  # noqa: E402

import numpy as np  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.configs.base import SHAPES, RunConfig  # noqa: E402
from repro.launch.dryrun import GRAD_ACCUM, LEVERS, _scaled_cfg, build_lowered  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import sharding as SH  # noqa: E402
from repro.roofline.analysis import COLLECTIVE_RE, SHAPE_RE, DTYPE_BYTES, _line_bytes  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--levers", default="")
    ap.add_argument("--layers", type=int, default=0, help="0 → one period")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    run_kw = {}
    for lv in [x for x in args.levers.split(",") if x]:
        cfg, run_kw = LEVERS[lv](cfg, run_kw)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    from repro.launch.dryrun import _layer_period

    L1 = args.layers or _layer_period(cfg)
    cfg1 = _scaled_cfg(cfg, L1, scan=False)
    if shape.kind == "train":
        sizes = SH.mesh_axis_sizes(mesh)
        bs = int(np.prod([sizes.get(a, 1) for a in ("pod", "data")]))
        a_eff = max(1, min(GRAD_ACCUM.get(args.arch, 1), shape.global_batch // bs))
        shape = dataclasses.replace(shape, global_batch=shape.global_batch // a_eff)
    run = RunConfig(model=cfg1, shape=shape, grad_accum=1, **run_kw)
    lowered, _ = build_lowered(cfg1, shape, mesh, run)
    compiled = lowered.compile()
    txt = compiled.as_text()

    # ---- largest collectives
    colls = []
    for line in txt.splitlines():
        m = COLLECTIVE_RE.match(line)
        if m:
            colls.append((_line_bytes(line), m.group(3), line.strip()[:240]))
    colls.sort(reverse=True)
    print(f"=== top collectives ({L1} layers, A=1) — per-device output bytes")
    for b, kind, line in colls[: args.top]:
        print(f"{b/1e6:10.1f} MB  {kind:18s} {line[:170]}")
    total = sum(b for b, _, _ in colls)
    by_kind = collections.Counter()
    for b, kind, _ in colls:
        by_kind[kind] += b
    print(f"total collective: {total/1e9:.2f} GB   by kind:",
          {k: f"{v/1e9:.2f}GB" for k, v in by_kind.items()})

    # ---- largest single ops by output bytes (traffic proxy)
    ops = []
    for line in txt.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*", s)
        if not m or "fusion" in s[:60] or "parameter(" in s:
            continue
        b = _line_bytes(s)
        if b > 0:
            opname = s.split("=", 1)[1].strip().split("(")[0].split(" ")[-1]
            ops.append((b, opname, s[:170]))
    ops.sort(reverse=True)
    print(f"\n=== top non-fusion ops by output bytes")
    seen = collections.Counter()
    shown = 0
    for b, op, line in ops:
        if seen[op] >= 3:
            continue
        seen[op] += 1
        print(f"{b/1e6:10.1f} MB  {line[:170]}")
        shown += 1
        if shown >= args.top:
            break

    from repro import compat

    ca = compat.cost_analysis(compiled)
    print(f"\nflops={ca.get('flops',0):.3e}  bytes={ca.get('bytes accessed',0):.3e}")
    mem = compiled.memory_analysis()
    print(f"temp={mem.temp_size_in_bytes/1e9:.2f}GB arg={mem.argument_size_in_bytes/1e9:.2f}GB")


if __name__ == "__main__":
    main()
