"""Training launcher: ``python -m repro.launch.train --arch <id> [k=v ...]``.

Runs the real Trainer (checkpoint/restart, straggler watchdog) on whatever
devices exist.  On this CPU container use ``--smoke`` for the reduced
config; on a TPU fleet drop the flag and set ``--mesh`` axes.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import registry
from repro.configs.base import RunConfig, ShapeConfig
from repro.models.common import NO_SHARD
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(registry.ARCHS))
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--grad-compression", default="none", choices=["none", "int8"])
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, smoke=args.smoke)
    api = registry.get_model_api(cfg)
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("cli", args.seq, args.batch, "train"),
        learning_rate=args.lr,
        total_steps=args.steps,
        warmup_steps=max(args.steps // 10, 1),
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every,
        grad_compression=args.grad_compression,
    )
    tr = Trainer(cfg, run, api, rules=NO_SHARD)
    print(f"training {cfg.name} ({sum(x.size for x in jax.tree.leaves(tr.state['params'])):,} params) "
          f"for {args.steps} steps on {len(jax.devices())} device(s)")
    log = tr.run_steps(args.steps)
    print(f"loss: {log[0]['loss']:.4f} -> {log[-1]['loss']:.4f}; "
          f"stragglers={len(tr.straggler_steps)} restarts={tr.restarts}")


if __name__ == "__main__":
    main()
