"""Batched serving engine: prefill + decode with continuous-batch shaping.

Batch formation uses the paper's technique: requests are **sorted by
prompt length** with the framework's sort primitive — now routed through
``repro.core.engine.SortEngine.sort_pairs`` (the bitonic pair-sort kernel
behind a power-of-two shape-bucketed jit cache, DESIGN.md §4), so each
padded prefill batch wastes the minimum number of pad tokens — the
serving-side face of the Array Division Procedure (DESIGN.md §3) — and a
stream of varying batch sizes reuses a handful of compiled executables
instead of recompiling per size.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import partition
from repro.core.engine import SortEngine
from repro.models.common import AxisRules, NO_SHARD


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray  # (len,) int32 token ids
    max_new_tokens: int = 16


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, model_api, *, rules: AxisRules = NO_SHARD,
                 max_len: int = 512, sorter: SortEngine | None = None):
        self.cfg, self.params, self.api = cfg, params, model_api
        self.rules = rules
        self.max_len = max_len
        self.sorter = sorter if sorter is not None else SortEngine()
        self._prefill = jax.jit(
            lambda p, b, c: model_api.prefill(p, b, cfg, rules, c)
        )
        self._decode = jax.jit(
            lambda p, t, c, pos: model_api.decode_step(p, t, cfg, rules, c, pos)
        )

    # ------------------------------------------------------- batch formation
    def order_by_length(self, requests: list[Request]) -> list[Request]:
        """Sort requests by prompt length via the engine's warm pair-sort path.

        One device call and one host transfer per batch (the permutation must
        come back to reorder a Python list); the sorted *payloads* of the
        segmented batch path stay on device (``SortEngine.sort_segments`` with
        ``return_padded=True``, DESIGN.md §8) — only this index sort syncs.
        """
        if len(requests) <= 1:
            return list(requests)
        lens = jnp.asarray([len(r.prompt) for r in requests], jnp.int32)
        idx = jnp.arange(len(requests), dtype=jnp.int32)
        _, order = self.sorter.sort_pairs(lens, idx)
        return [requests[int(i)] for i in np.asarray(order)]

    def _pad_batch(self, requests: list[Request]):
        lens = [len(r.prompt) for r in requests]
        L = max(lens)
        # left-pad → aligned ends (right-aligned content): one vectorized
        # pack instead of a per-request copy loop
        toks = partition.pack_segments(
            np.concatenate([r.prompt for r in requests]) if requests else
            np.zeros(0, np.int32),
            lens, L, fill_value=0, align="right",
        ).astype(np.int32)
        return jnp.asarray(toks), L

    # --------------------------------------------------------------- serving
    def generate(self, requests: list[Request], greedy: bool = True) -> dict[int, list[int]]:
        if not requests:
            # _pad_batch's max() over an empty sequence raised a bare
            # ValueError here; an empty batch is simply an empty result.
            return {}
        requests = self.order_by_length(requests)
        toks, L = self._pad_batch(requests)
        B = toks.shape[0]
        batch = {"tokens": toks}
        if self.cfg.family == "encdec":
            batch["enc_frames"] = jnp.zeros(
                (B, self.cfg.encoder_seq_len, self.cfg.d_model), self.cfg.dtype
            )
        cache = self.api.init_cache(self.cfg, B, self.max_len)
        logits, cache = self._prefill(self.params, batch, cache)
        out = {r.id: [] for r in requests}
        steps = max(r.max_new_tokens for r in requests)
        tok = jnp.argmax(logits, -1)[:, None]
        for s in range(steps):
            for i, r in enumerate(requests):
                if s < r.max_new_tokens:
                    out[r.id].append(int(tok[i, 0]))
            if s + 1 < steps:  # the last emitted token needs no decode step
                logits, cache = self._decode(self.params, tok, cache, L + s)
                tok = jnp.argmax(logits, -1)[:, None]
        assert all(len(out[r.id]) == r.max_new_tokens for r in requests)
        return out
