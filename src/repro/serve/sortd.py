"""sortd — adaptive micro-batching sort service over ``SortEngine``
(DESIGN.md §8).

The paper's evaluation is many concurrent sorts over one OHHC, and its
related work measures that the *mode of execution* — not the algorithm —
dominates throughput.  sortd is that layer for this repo: callers submit
individual sort requests; a single worker thread coalesces them into
micro-batches and serves each batch with ONE fused device call
(``SortEngine.sort_segments``), so P small requests cost one dispatch, one
transfer, and one warm-cache executable instead of P of each.

Mechanics:

* **Bounded request queue** (``SortdConfig.max_queue``): admission control.
  When full, ``submit`` either raises :class:`QueueFull` immediately or
  blocks (``block_on_full``) — backpressure propagates to producers instead
  of growing an unbounded backlog.
* **Adaptive coalescing**: requests bin by ``(dtype, pow2 shape bucket)`` —
  the same bucketing rule as the engine's warm jit cache
  (``repro.kernels.ops.bucketed_length``), so every flush lands on an
  already-compiled executable.  Mixed dtypes are never coalesced (a fused
  batch is one device array), and rows only ever pad within their own
  bucket, which bounds per-batch pad waste below 50% + the deadline's
  short-row tail.
* **Max-wait deadline** (``max_wait_s``): a bin flushes when it reaches
  ``max_batch`` rows (reason ``full``) or when its *oldest* request has
  waited the deadline (reason ``deadline``) — latency is bounded even at
  one request per epoch, throughput is batched under load.  The adaptive
  part is exactly this pair: at low arrival rates the deadline dominates
  (batch of 1, latency ≈ max_wait), at high rates ``max_batch`` dominates
  (amortization without waiting).
* **Oversize fallback**: requests longer than ``max_bucket`` never coalesce
  (their pad waste would dominate a batch); they are served inline through
  the engine's own per-array dispatch (``SortEngine.sort`` — which may
  itself pick the host path for huge inputs).
* **Metrics**: per-request latency (p50/p99 over a sliding window) and
  pad-waste per shape bucket, flush-reason counters, queue depth highwater,
  rejected count — ``metrics()`` returns a JSON-ready dict; the ``sortd``
  benchmark suite and ``tools/verify.py --sortd`` read it.

Threading contract: any number of producer threads may call ``submit``;
all engine/device work happens on the single worker thread, so the jit
cache and ``last_report`` see strictly serial traffic.

Fleet hooks (DESIGN.md §10): ``repro.serve.fleet`` runs N of these
workers behind one admission layer, which needs three seams this module
owns:

* **Idle flush** (``SortdConfig.idle_flush_s``): the coalescing deadline
  (``max_wait_s``) buys batch size only while traffic is still arriving;
  when the request queue is *empty* — every producer is blocked on a
  Future — waiting out the full deadline is pure idle time (measured:
  30–50% of wall under closed-loop load).  With ``idle_flush_s`` set, a
  bin whose oldest request has waited that long flushes early (reason
  ``idle``) whenever the queue is empty; under sustained arrival the
  queue is non-empty and the full ``max_wait_s`` still governs.  Off
  (``None``) by default — standalone sortd behavior is unchanged.
* **Tick hooks** (``add_tick_hook``): callbacks run on the worker thread
  once per loop iteration and after every flush — the fleet's heartbeat
  (and chaos stall-injection) point.  ``tick_interval_s`` caps the idle
  queue wait so a traffic-less worker still ticks.
* **Crash simulation** (``kill()``): the worker thread aborts at its next
  tick *without* draining or flushing — queued requests are left as
  dangling futures, exactly what a real worker crash does.  The fleet's
  health checker detects the dead thread and re-admits the backlog from
  its own bookkeeping (:class:`WorkerKilled` is the internal control
  exception).  ``close()`` after a kill still joins cleanly; only the
  fleet layer guarantees the orphaned work is served.
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from concurrent.futures import Future

import numpy as np

from repro.core import workloads
from repro.core.engine import SortEngine
from repro.kernels import ops

__all__ = ["Sortd", "SortdConfig", "QueueFull", "WorkerKilled", "affinity_key"]


class QueueFull(RuntimeError):
    """Raised by ``submit`` when the bounded queue is at capacity."""


class WorkerKilled(BaseException):
    """Control exception aborting the worker thread on ``kill()``.

    Derives from ``BaseException`` so the per-flush ``except Exception``
    guards can never swallow a chaos kill into a batch failure.
    """


def affinity_key(arr: np.ndarray) -> "tuple[str, int]":
    """The ``(dtype, pow2 shape bucket)`` coalescing/affinity key.

    One rule shared by the sortd bins, the engine's warm jit cache, and
    the fleet's affinity router — same key ⇒ same bin ⇒ same compiled
    executable ⇒ (in a fleet) same worker.
    """
    return (str(arr.dtype), ops.bucketed_length(max(arr.size, 1)))


@dataclasses.dataclass(frozen=True)
class SortdConfig:
    """Tuning knobs for the micro-batching service.

    max_queue:      bounded request queue length (backpressure boundary).
    max_batch:      flush a bin when it holds this many rows.
    max_wait_s:     flush a bin when its oldest row has waited this long.
    max_bucket:     largest coalescible shape bucket; longer requests take
                    the direct per-array engine path.
    block_on_full:  submit blocks (True) or raises QueueFull (False).
    latency_window: per-bucket sliding-window size for the percentiles.
    idle_flush_s:   with the request queue EMPTY, flush a bin once its
                    oldest row has waited this long (reason ``idle``) —
                    waiting out max_wait_s with no traffic arriving is
                    pure idle time.  None (default) disables; must be
                    < max_wait_s to have any effect.
    tick_interval_s: upper bound on the idle queue wait so tick hooks
                    (fleet heartbeats) keep firing with no traffic.
                    None (default) lets an idle worker sleep until the
                    next request.
    """

    max_queue: int = 1024
    max_batch: int = 64
    max_wait_s: float = 0.005
    max_bucket: int = 1 << 15
    block_on_full: bool = False
    latency_window: int = 4096
    idle_flush_s: "float | None" = None
    tick_interval_s: "float | None" = None


@dataclasses.dataclass
class _Pending:
    keys: np.ndarray
    t_enqueue: float
    future: Future
    # Workload tag (DESIGN.md §12): "sort" coalesces as before; "merge"
    # carries the caller's already-sorted buffer and bins under its own
    # op-prefixed key, so merge and sort traffic on the same
    # (dtype, bucket) never share a batch.
    op: str = "sort"
    buf: "np.ndarray | None" = None


class _Stop:
    pass


class _Nudge:
    """Queue no-op: wakes the worker loop (kill/tick) without carrying work."""


_STOP = _Stop()
_NUDGE = _Nudge()


class _BucketStats:
    __slots__ = (
        "requests", "batches", "rows", "pad_cells", "valid_cells", "lat_s",
        "methods",
    )

    def __init__(self, window: int):
        self.requests = 0
        self.batches = 0
        self.rows = 0
        self.pad_cells = 0
        self.valid_cells = 0
        self.lat_s = collections.deque(maxlen=window)
        # flush count per executed plan method (e.g. bitonic vs
        # bitonic_pallas vs bitonic2op) — names the kernel the engine's
        # row-backend autotune actually ran for this bucket's traffic
        self.methods: dict[str, int] = {}


class Sortd:
    """The service.  Use as a context manager or call ``close()`` yourself.

    >>> with Sortd(SortEngine()) as sd:
    ...     fut = sd.submit(np.array([3, 1, 2], np.int32))
    ...     fut.result()
    array([1, 2, 3], dtype=int32)
    """

    def __init__(
        self,
        engine: SortEngine | None = None,
        config: SortdConfig | None = None,
        *,
        start: bool = True,
    ):
        self.engine = engine if engine is not None else SortEngine()
        self.config = config if config is not None else SortdConfig()
        self._queue: queue.Queue = queue.Queue(maxsize=self.config.max_queue)
        self._bins: dict[tuple[str, int], list[_Pending]] = {}
        self._lock = threading.Lock()  # guards metrics only
        # Serializes the closed-check-then-enqueue in submit() against
        # close(): without it a racing submit can enqueue after the worker
        # drained and exited, leaving a Future that never resolves.
        self._close_lock = threading.Lock()
        self._closed = False
        self._killed = False
        self._binned = 0  # rows currently sitting in bins (worker thread)
        self._thread: threading.Thread | None = None
        self._tick_hooks: list = []
        self._t_start = time.monotonic()
        self._busy_s = 0.0  # worker-thread cumulative flush/serve time
        # metrics (under _lock)
        self._completed = 0
        self._oversize_direct = 0
        self._rejected = 0
        self._failed = 0
        self._fault_name: "str | None" = None
        self._degraded_flushes = 0  # flushes served under an active fault
        self._flushes = {"full": 0, "deadline": 0, "idle": 0, "close": 0}
        self._max_queue_depth = 0
        self._buckets: dict[str, _BucketStats] = {}
        self._all_lat_s: collections.deque = collections.deque(
            maxlen=self.config.latency_window
        )
        if start:
            self.start()

    # ----------------------------------------------------------- lifecycle
    def start(self) -> "Sortd":
        """Start the worker thread (idempotent)."""
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._run, name="sortd-worker", daemon=True
            )
            self._thread.start()
        return self

    def close(self) -> None:
        """Stop accepting requests, flush everything queued, join the worker.

        Drain guarantee: every request whose ``submit`` returned before
        ``close`` was called gets served — its Future resolves — before
        ``close`` returns (the fleet's failover re-admission leans on this
        invariant).  The single exception is a worker aborted by ``kill()``
        (chaos crash simulation): its backlog is intentionally left
        dangling, and only the fleet layer re-admits it.
        """
        with self._close_lock:
            if self._closed:
                return
            self._closed = True
            # Under the close lock: every submit that passed its closed-check
            # has already enqueued, so its item sits before this sentinel and
            # the worker's final drain serves it.  The put must not block
            # forever on a full queue whose worker crashed — poll liveness.
            if self._thread is not None:
                while True:
                    try:
                        self._queue.put(_STOP, timeout=0.1)
                        break
                    except queue.Full:
                        if not self._thread.is_alive():
                            break  # crashed worker: nobody will drain
        if self._thread is None:
            # never started: serve the backlog inline so no future dangles
            self._drain_queue()
            self._flush_all("close")
            return
        self._thread.join()
        self._thread = None

    def kill(self) -> None:
        """Chaos hook: simulate a worker crash (DESIGN.md §10).

        The worker thread aborts at its next tick WITHOUT flushing — all
        queued/binned requests are left as dangling futures, exactly like a
        real crash.  Safe to call from any thread; idempotent.
        """
        self._killed = True
        try:
            self._queue.put_nowait(_NUDGE)  # wake a blocked worker now
        except queue.Full:
            pass  # a full queue wakes the worker anyway

    def add_tick_hook(self, fn) -> None:
        """Register ``fn()`` to run on the worker thread each loop iteration
        and after every flush — the fleet heartbeat/chaos-injection seam."""
        self._tick_hooks.append(fn)

    def set_fault_scenario(self, scenario) -> None:
        """Serve under a degraded topology (DESIGN.md §11).

        Forwards a ``net.faults.FaultScenario`` (or ``None`` to heal) to
        the engine, whose fallback ladder does the actual work: flushes
        re-price their plans over the degraded schedule, and a scenario
        that makes the gather impossible reroutes every flush onto the
        healthy host path instead of erroring — callers see correct
        results either way, ``metrics()`` sees which scenario is live and
        how many flushes it degraded.  Safe from any thread: the engine
        reads the scenario once per plan, on the worker thread.
        """
        self.engine.set_fault_scenario(scenario)
        with self._lock:
            self._fault_name = (
                scenario.name
                if scenario is not None and getattr(scenario, "is_degraded", False)
                else None
            )

    def backlog(self) -> int:
        """Requests accepted but not yet served (queued + binned).

        Approximate under concurrency — good enough for the fleet's
        steal/health heuristics, never used for correctness.
        """
        return self._queue.qsize() + self._binned

    @property
    def worker_alive(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def __enter__(self) -> "Sortd":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ----------------------------------------------------------- submission
    def submit(self, keys) -> Future:
        """Enqueue one sort request; the Future resolves to the sorted array.

        Raises :class:`QueueFull` when the bounded queue is at capacity and
        ``block_on_full`` is off; blocks otherwise.  Raises RuntimeError
        after ``close()``.
        """
        arr = np.asarray(keys).ravel()
        return self._enqueue(_Pending(arr, time.monotonic(), Future()))

    def submit_merge(self, sorted_buf, new_keys) -> Future:
        """Enqueue an incremental merge; resolves to the merged array.

        The streaming workload (DESIGN.md §12): ``new_keys`` coalesces
        with other merge increments of the same (dtype, shape bucket) —
        one fused ``sort_segments`` call sorts every batch's increments —
        and each result then folds into its caller's ``sorted_buf`` with
        the O(n+m) gather.  Merge bins carry their own op-prefixed
        coalescing key, so they never share a batch with plain sort
        requests on the same (dtype, bucket).  The buffer is validated
        ascending at serve time; a bad buffer fails only its own future.
        """
        buf = np.asarray(sorted_buf).ravel()
        new = np.asarray(new_keys).ravel()
        if buf.dtype != new.dtype:
            raise ValueError(
                f"merge: dtype mismatch — buffer {buf.dtype} "
                f"vs new keys {new.dtype}"
            )
        return self._enqueue(
            _Pending(new, time.monotonic(), Future(), op="merge", buf=buf)
        )

    def merge(self, sorted_buf, new_keys, timeout: float | None = 60.0) -> np.ndarray:
        """Synchronous wrapper: ``submit_merge(...).result()``."""
        return self.submit_merge(sorted_buf, new_keys).result(timeout=timeout)

    def _enqueue(self, item: _Pending) -> Future:
        with self._close_lock:
            if self._closed:
                raise RuntimeError("sortd is closed")
            try:
                self._queue.put(item, block=self.config.block_on_full)
            except queue.Full:
                with self._lock:
                    self._rejected += 1
                raise QueueFull(
                    f"sortd queue at capacity ({self.config.max_queue})"
                ) from None
        with self._lock:
            self._max_queue_depth = max(self._max_queue_depth, self._queue.qsize())
        return item.future

    def sort(self, keys, timeout: float | None = 60.0) -> np.ndarray:
        """Synchronous convenience wrapper: ``submit(keys).result()``."""
        return self.submit(keys).result(timeout=timeout)

    # -------------------------------------------------------------- metrics
    def metrics(self) -> dict:
        """JSON-ready snapshot: latency percentiles + pad waste per bucket."""

        def pct(d, q):
            return float(np.percentile(np.asarray(d), q)) * 1e3 if d else 0.0

        with self._lock:
            buckets = {}
            for key, b in self._buckets.items():
                total_cells = b.pad_cells + b.valid_cells
                buckets[key] = {
                    "requests": b.requests,
                    "batches": b.batches,
                    "mean_batch": b.rows / b.batches if b.batches else 0.0,
                    "p50_ms": pct(b.lat_s, 50),
                    "p99_ms": pct(b.lat_s, 99),
                    "pad_waste": b.pad_cells / total_cells if total_cells else 0.0,
                    "methods": dict(b.methods),
                }
            return {
                "completed": self._completed,
                "failed": self._failed,
                "oversize_direct": self._oversize_direct,
                "rejected": self._rejected,
                "fault_scenario": self._fault_name,
                "degraded_flushes": self._degraded_flushes,
                "flushes": dict(self._flushes),
                "queue_depth": self._queue.qsize(),
                "max_queue_depth": self._max_queue_depth,
                "busy_s": self._busy_s,
                "uptime_s": time.monotonic() - self._t_start,
                "latency_ms": {
                    "p50": pct(self._all_lat_s, 50),
                    "p99": pct(self._all_lat_s, 99),
                },
                "buckets": buckets,
            }

    # ------------------------------------------------------------- worker
    def _bin_key(self, item: _Pending) -> tuple[str, str, int]:
        # op-prefixed: "merge" increments never coalesce with "sort"
        # requests of the same (dtype, bucket) — batches stay homogeneous
        return (item.op,) + affinity_key(item.keys)

    def _beat(self) -> None:
        for fn in self._tick_hooks:
            fn()

    def _tick(self) -> None:
        self._beat()
        if self._killed:
            raise WorkerKilled("chaos kill")

    def _wait_budget(self) -> float:
        """How long the oldest binned request may wait before a flush.

        ``max_wait_s`` while traffic is arriving; the (shorter)
        ``idle_flush_s`` once the queue is empty — every producer is then
        blocked on a Future and further waiting buys no batch size.
        """
        cfg = self.config
        if (
            cfg.idle_flush_s is not None
            and cfg.idle_flush_s < cfg.max_wait_s
            and self._queue.qsize() == 0
        ):
            return cfg.idle_flush_s
        return cfg.max_wait_s

    def _next_deadline(self) -> float | None:
        if not self._bins:
            return None
        oldest = min(batch[0].t_enqueue for batch in self._bins.values())
        return oldest + self._wait_budget()

    def _run(self) -> None:
        try:
            self._run_loop()
        except WorkerKilled:
            return  # simulated crash: exit without draining or flushing

    def _run_loop(self) -> None:
        while True:
            self._tick()
            deadline = self._next_deadline()
            timeout = (
                max(0.0, deadline - time.monotonic()) if deadline is not None else None
            )
            tick_s = self.config.tick_interval_s
            if tick_s is not None:
                timeout = tick_s if timeout is None else min(timeout, tick_s)
            try:
                item = self._queue.get(timeout=timeout)
            except queue.Empty:
                item = None
            stop = isinstance(item, _Stop)
            if item is not None and not stop and not isinstance(item, _Nudge):
                self._route(item)
            if not stop:
                # Greedy drain: coalesce the backlog before looking at
                # deadlines.  Without this, a backlog built up during a long
                # flush arrives one item per wakeup with its deadline already
                # expired — every flush degenerates to batch size 1 exactly
                # when the server is overloaded (the anti-batching death
                # spiral).  _route flushes any bin that reaches max_batch.
                # The drain is BUDGETED at max_queue items: producers with
                # block_on_full refill the queue as fast as it drains, and an
                # unbounded drain would then starve a lone expired request in
                # a cold (dtype, bucket) bin forever — the budget caps the
                # wait at one backlog's worth of routing before deadlines are
                # honored again.  (Breaking out as soon as any deadline has
                # expired is wrong the other way: a burst that arrives during
                # a flush is entirely past its deadline, and per-item breaks
                # would flush it one request at a time.)
                budget = max(self.config.max_queue, 1)
                while budget > 0:
                    try:
                        nxt = self._queue.get_nowait()
                    except queue.Empty:
                        break
                    if isinstance(nxt, _Stop):
                        stop = True
                        break
                    if isinstance(nxt, _Nudge):
                        continue
                    self._route(nxt)
                    budget -= 1
            if stop:
                self._drain_queue()
                self._flush_all("close")
                return
            self._flush_expired()

    def _drain_queue(self) -> None:
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                return
            if not isinstance(item, (_Stop, _Nudge)):
                self._route(item)

    def _route(self, item: _Pending) -> None:
        if item.keys.size > self.config.max_bucket:
            self._serve_direct(item)
            return
        key = self._bin_key(item)
        self._bins.setdefault(key, []).append(item)
        self._binned += 1
        if len(self._bins[key]) >= self.config.max_batch:
            self._flush(key, "full")

    def _flush_expired(self) -> None:
        now = time.monotonic()
        budget = self._wait_budget()
        for key in [
            k
            for k, batch in self._bins.items()
            if now - batch[0].t_enqueue >= budget
        ]:
            waited = now - self._bins[key][0].t_enqueue
            reason = "deadline" if waited >= self.config.max_wait_s else "idle"
            self._flush(key, reason)

    def _flush_all(self, reason: str) -> None:
        for key in list(self._bins):
            self._flush(key, reason)

    def _flush(self, key: tuple[str, str, int], reason: str) -> None:
        batch = self._bins.pop(key)
        self._binned -= len(batch)
        t_busy0 = time.monotonic()
        op, dtype_str, bucket = key
        lens = [p.keys.size for p in batch]
        try:
            flat = (
                np.concatenate([p.keys for p in batch])
                if len(batch) > 1
                else batch[0].keys
            )
            outs = self.engine.sort_segments(flat, lens)
            plan = (self.engine.last_report or {}).get("plan")
            method = getattr(plan, "method", None) or "?"
            fault = getattr(plan, "fault", None)
        except Exception as e:  # one bad batch must not kill its siblings' futures
            self._busy_s += time.monotonic() - t_busy0
            with self._lock:
                self._failed += len(batch)
            for p in batch:
                p.future.set_exception(e)
            return
        errs: "list[Exception | None]" = [None] * len(batch)
        if op == "merge":
            # Merge batch (DESIGN.md §12): the fused call above sorted
            # every increment; fold each into its caller's buffer with the
            # O(n+m) gather.  check=True validates the buffer ascending —
            # a bad buffer fails only ITS future, never its batch-mates'.
            merged: list = []
            for i, (p, out) in enumerate(zip(batch, outs)):
                try:
                    merged.append(
                        workloads.merge_sorted_arrays(
                            p.buf, np.asarray(out), check=True
                        )
                    )
                except Exception as e:
                    merged.append(None)
                    errs[i] = e
            outs = merged
        done = time.monotonic()
        self._busy_s += done - t_busy0
        lats = [done - p.t_enqueue for p in batch]
        n_err = sum(1 for e in errs if e is not None)
        # Account BEFORE resolving: a caller that wakes on the last future
        # and immediately reads metrics() must see these requests counted.
        with self._lock:
            self._flushes[reason] += 1
            if fault is not None:
                self._degraded_flushes += 1
            self._completed += len(batch) - n_err
            self._failed += n_err
            self._all_lat_s.extend(lats)
            label = (
                f"{dtype_str}/{bucket}"
                if op == "sort"
                else f"{op}/{dtype_str}/{bucket}"
            )
            b = self._bucket_stats(label)
            b.requests += len(batch)
            b.batches += 1
            b.rows += len(batch)
            b.valid_cells += int(sum(lens))
            b.pad_cells += len(batch) * bucket - int(sum(lens))
            b.lat_s.extend(lats)
            b.methods[method] = b.methods.get(method, 0) + 1
        for p, out, err in zip(batch, outs, errs):
            if err is not None:
                p.future.set_exception(err)
            else:
                p.future.set_result(out)
        self._beat()  # heartbeat between flushes of a long backlog

    def _serve_direct(self, item: _Pending) -> None:
        t_busy0 = time.monotonic()
        try:
            if item.op == "merge":
                out = self.engine.merge_sorted(item.buf, item.keys)
            else:
                out = self.engine.sort(item.keys)
        except Exception as e:
            self._busy_s += time.monotonic() - t_busy0
            with self._lock:
                self._failed += 1
            item.future.set_exception(e)
            return
        done = time.monotonic()
        self._busy_s += done - t_busy0
        lat = done - item.t_enqueue
        label = (
            f"{item.keys.dtype}/direct"
            if item.op == "sort"
            else f"{item.op}/{item.keys.dtype}/direct"
        )
        with self._lock:  # account before resolving (see _flush)
            self._oversize_direct += 1
            self._completed += 1
            self._all_lat_s.append(lat)
            b = self._bucket_stats(label)
            b.requests += 1
            b.batches += 1
            b.rows += 1
            b.valid_cells += item.keys.size
            b.lat_s.append(lat)
        item.future.set_result(out)
        self._beat()

    def _bucket_stats(self, key: str) -> _BucketStats:
        b = self._buckets.get(key)
        if b is None:
            b = self._buckets[key] = _BucketStats(self.config.latency_window)
        return b
