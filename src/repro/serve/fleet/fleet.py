"""``SortdFleet`` — N sortd workers behind one admission layer, with
affinity routing, work stealing, health-checked failover, and chaos
injection (DESIGN.md §10).

The paper's pitch is that many cooperating processors beat one; the
serving translation is N :class:`~repro.serve.sortd.Sortd` workers (each
with its OWN :class:`~repro.core.engine.SortEngine` — per-worker jit/plan
cache isolation; one per device when a mesh exists, N threads on one
device otherwise) behind a single ``submit``.  What the fleet adds over
one bigger sortd:

* **Admission + routing**: ``submit`` is the shared admission point
  (bounded by ``max_inflight`` — ``QueueFull`` or blocking backpressure,
  same contract as sortd).  Routing is the client thread running
  :class:`~repro.serve.fleet.routing.AffinityRouter` — no dispatcher
  thread, no extra hop on the hot path.  Affinity keeps each ``(dtype,
  pow2 bucket)`` on one warm worker; the steal watermark redirects
  admissions away from a backlogged worker.
* **Failover** (the Ghosh & Ghosh OTIS fault-tolerance regime as a
  serving property): the fleet tracks every admitted-but-unresolved job
  per worker; when the health monitor declares a worker dead (crashed
  thread or stale heartbeat), the worker is drained — its unresolved
  jobs re-admitted to survivors — so a dead worker costs latency, never
  an answer.  Resolution is first-wins: a stalled worker that recovers
  after its jobs were re-admitted just produces harmless duplicates
  (sorting is deterministic; the first ``set_result`` sticks).
* **Chaos** (:class:`ChaosConfig`): deterministic fault injection in the
  ``FaultScenario`` mold — ``kill_worker_after`` admissions crashes a
  worker mid-load via ``Sortd.kill()`` (futures dangle, exactly like a
  real crash), ``stall_worker_ms`` freezes one via its tick hook.
  ``ChaosConfig.scenario()`` names the matching simulator-side
  ``FaultScenario.worker_down`` so the fleet and ``net.faults`` speak one
  vocabulary.
* **Observability**: ``metrics()`` is per-worker (state, backlog,
  admitted/completed, busy fraction, embedded sortd metrics) plus
  fleet-wide (p50/p99 over the fleet latency window, steals, failovers,
  re-admissions, saturation, aggregate pad waste); ``report()`` +
  :func:`write_json` produce the JSON artifact, mirroring
  ``repro.net.report``.

Throughput note, measured on this 1-core container: fleet workers default
to ``idle_flush_s`` (see DESIGN.md §10) — eliminating the single-sortd
deadline idle is where the ≥2× closed-loop win comes from on one core; on
a real multi-core/multi-device host, compute parallelism stacks on top.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Callable, Sequence

import numpy as np

from repro.core.engine import SortEngine
from repro.net.faults import FaultScenario
from repro.serve.fleet.health import HealthMonitor, WorkerState
from repro.serve.fleet.routing import AffinityRouter
from repro.serve.sortd import QueueFull, Sortd, SortdConfig, affinity_key

__all__ = ["SortdFleet", "FleetConfig", "ChaosConfig", "FleetDown", "write_json"]


class FleetDown(RuntimeError):
    """No live worker remains to serve or re-admit a job."""


def _default_worker_config() -> SortdConfig:
    # Smaller per-worker queue than a standalone sortd (the fleet's
    # max_inflight is the real admission bound; a full worker queue just
    # triggers overflow-stealing) + the fleet scheduling knobs: idle flush
    # on, ticks frequent enough to heartbeat.  block_on_full must stay
    # False — the fleet calls worker.submit under its admission lock.
    return SortdConfig(
        max_queue=256,
        max_bucket=1 << 12,
        idle_flush_s=1e-4,
        tick_interval_s=0.02,
        block_on_full=False,
    )


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Fleet-level knobs; per-worker knobs ride in ``worker_config``.

    workers:              worker count (one engine + one sortd each).
    steal_watermark:      affine backlog depth that arms admission-side
                          stealing (see routing module).
    steal_margin:         required load ratio before a steal fires.
    max_inflight:         fleet-wide admission bound (backpressure).
    block_on_full:        submit blocks (True) or raises QueueFull (False).
    heartbeat_interval_s: health probe period (and worker tick cap).
    heartbeat_timeout_s:  stale-heartbeat threshold — must exceed the
                          worst single direct sort or a slow worker is
                          declared dead (costing duplicate work only).
    latency_window:       fleet-wide sliding window for p50/p99.
    worker_config:        SortdConfig for every worker (block_on_full and
                          tick_interval_s are overridden by the fleet).
    """

    workers: int = 4
    steal_watermark: int = 8
    steal_margin: int = 2
    max_inflight: int = 4096
    block_on_full: bool = False
    heartbeat_interval_s: float = 0.02
    heartbeat_timeout_s: float = 1.0
    latency_window: int = 8192
    worker_config: SortdConfig = dataclasses.field(
        default_factory=_default_worker_config
    )


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Deterministic fault injection, ``FaultScenario``-style.

    kill_worker_after: fleet admission count at which the kill fires
                       (None disables).
    kill_worker:       victim index, or "busiest" = largest backlog at
                       trigger time (guarantees a non-trivial drain).
    stall_worker_ms:   one-shot stall length injected on the victim's
                       worker thread (0 disables).
    stall_worker:      stall victim index.
    stall_worker_after: admission count arming the stall.
    """

    name: str = "none"
    kill_worker_after: "int | None" = None
    kill_worker: "int | str" = "busiest"
    stall_worker_ms: float = 0.0
    stall_worker: int = 0
    stall_worker_after: int = 0

    def scenario(self, worker: int) -> FaultScenario:
        """The simulator-vocabulary twin of killing ``worker`` (shared
        naming with ``net.faults`` degraded-schedule scenarios)."""
        return FaultScenario.worker_down(worker)


class _Job:
    __slots__ = ("id", "keys", "key", "future", "t_submit", "worker",
                 "attempts", "resolved")

    def __init__(self, jid: int, keys: np.ndarray, key) -> None:
        self.id = jid
        self.keys = keys
        self.key = key
        self.future: Future = Future()
        self.t_submit = time.monotonic()
        self.worker = -1
        self.attempts = 0
        self.resolved = False


class _Worker:
    __slots__ = ("wid", "engine", "sortd", "inflight", "admitted",
                 "completed", "steals_in", "state", "dead_reason",
                 "last_beat", "stall_ms_pending")

    def __init__(self, wid: int, engine: SortEngine, sortd: Sortd) -> None:
        self.wid = wid
        self.engine = engine
        self.sortd = sortd
        self.inflight: "dict[int, _Job]" = {}
        self.admitted = 0
        self.completed = 0
        self.steals_in = 0
        self.state = WorkerState.LIVE
        self.dead_reason: "str | None" = None
        self.last_beat = time.monotonic()
        self.stall_ms_pending = 0.0


class SortdFleet:
    """Use as a context manager or call ``close()`` yourself.

    >>> with SortdFleet(FleetConfig(workers=2)) as fleet:
    ...     fleet.sort(np.array([3, 1, 2], np.int32))
    array([1, 2, 3], dtype=int32)
    """

    def __init__(
        self,
        config: "FleetConfig | None" = None,
        *,
        engine_factory: "Callable[[int], SortEngine] | None" = None,
        chaos: "ChaosConfig | None" = None,
        start: bool = True,
    ):
        self.config = config if config is not None else FleetConfig()
        if self.config.workers < 1:
            raise ValueError("a fleet needs at least one worker")
        self.chaos = chaos
        self._lock = threading.RLock()
        self._not_full = threading.Condition(self._lock)
        self._closed = False
        self._job_ids = itertools.count()
        self._router = AffinityRouter(
            steal_watermark=self.config.steal_watermark,
            steal_margin=self.config.steal_margin,
        )
        wcfg = dataclasses.replace(
            self.config.worker_config,
            block_on_full=False,
            tick_interval_s=self.config.heartbeat_interval_s,
        )
        factory = engine_factory if engine_factory is not None else (
            lambda wid: SortEngine()
        )
        self._workers: "list[_Worker]" = []
        for wid in range(self.config.workers):
            sortd = Sortd(factory(wid), wcfg, start=False)
            w = _Worker(wid, sortd.engine, sortd)
            sortd.add_tick_hook(lambda w=w: self._worker_tick(w))
            self._workers.append(w)
        self._live: "set[int]" = set(range(self.config.workers))
        self._monitor = HealthMonitor(
            interval_s=self.config.heartbeat_interval_s,
            timeout_s=self.config.heartbeat_timeout_s,
            on_dead=self._on_worker_dead,
        )
        for w in self._workers:
            self._monitor.register(
                w.wid,
                alive=(lambda w=w: w.sortd.worker_alive),
                last_beat=(lambda w=w: w.last_beat),
            )
        # metrics (under _lock)
        self._inflight_total = 0
        self._admitted = 0
        self._completed = 0
        self._failed = 0
        self._rejected = 0
        self._steals = 0
        self._failovers = 0
        self._readmitted = 0
        self._lat_s: "list[float]" = []
        self._t_start = time.monotonic()
        # chaos arming
        self._chaos_killed: "int | None" = None
        self._chaos_stalled: "int | None" = None
        # degraded serving (DESIGN.md §11)
        self._fault_scenario: "FaultScenario | None" = None
        self._fault_summary: "dict | None" = None
        if start:
            self.start()

    # ----------------------------------------------------------- lifecycle
    def start(self) -> "SortdFleet":
        for w in self._workers:
            w.sortd.start()
        self._monitor.start()
        return self

    def __enter__(self) -> "SortdFleet":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Drain every live worker, resolve every admitted job, stop.

        Jobs stranded on a crashed-but-not-yet-drained worker are served
        inline here — ``close`` never leaves an admitted future dangling.
        """
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._not_full.notify_all()
        self._monitor.stop()
        for w in self._workers:
            if w.state is WorkerState.LIVE:
                w.sortd.close()  # flush-drain; callbacks resolve our jobs
        # Final sweep: anything still unresolved (crashed worker backlog
        # that the monitor had not drained yet) is served inline.
        with self._lock:
            stranded = [
                j
                for w in self._workers
                for j in list(w.inflight.values())
                if not j.resolved
            ]
            for w in self._workers:
                w.inflight.clear()
        for job in stranded:
            try:
                out = self._workers[0].engine.sort(job.keys)
            except Exception as e:  # noqa: BLE001
                self._resolve(job, error=e)
            else:
                self._resolve(job, result=out)

    # ----------------------------------------------------------- admission
    def submit(self, keys) -> Future:
        """Route one request to a worker; the Future resolves to the
        sorted array (from the first worker to finish it, under chaos)."""
        arr = np.asarray(keys).ravel()
        key = affinity_key(arr)
        job = _Job(next(self._job_ids), arr, key)
        with self._lock:
            if self._closed:
                raise RuntimeError("fleet is closed")
            while self._inflight_total >= self.config.max_inflight:
                if not self.config.block_on_full:
                    self._rejected += 1
                    raise QueueFull(
                        f"fleet at max_inflight ({self.config.max_inflight})"
                    )
                self._not_full.wait(0.1)
                if self._closed:
                    raise RuntimeError("fleet is closed")
            self._admitted += 1
            self._maybe_trigger_chaos()
            wid = self._pick_worker(key)
            self._place(job, wid, new=True)
        self._dispatch(job)
        return job.future

    def sort(self, keys, timeout: "float | None" = 60.0) -> np.ndarray:
        """Synchronous convenience wrapper: ``submit(keys).result()``."""
        return self.submit(keys).result(timeout=timeout)

    # ------------------------------------------------------------- workers
    def _worker_tick(self, w: _Worker) -> None:
        # Runs on w's worker thread: heartbeat + one-shot chaos stall.
        w.last_beat = time.monotonic()
        if w.stall_ms_pending > 0.0:
            stall, w.stall_ms_pending = w.stall_ms_pending, 0.0
            time.sleep(stall / 1e3)

    def _backlogs(self) -> "dict[int, int]":
        return {w.wid: len(w.inflight) for w in self._workers}

    def _pick_worker(self, key) -> int:
        # under _lock
        if not self._live:
            raise FleetDown("no live workers")
        decision = self._router.route(key, sorted(self._live), self._backlogs())
        if decision.stolen:
            self._steals += 1
            self._workers[decision.worker].steals_in += 1
        return decision.worker

    def _place(self, job: _Job, wid: int, *, new: bool) -> None:
        # under _lock
        w = self._workers[wid]
        job.worker = wid
        job.attempts += 1
        w.inflight[job.id] = job
        w.admitted += 1
        if new:
            self._inflight_total += 1

    def _dispatch(self, job: _Job) -> None:
        """Hand the placed job to its worker's sortd (outside the lock)."""
        w = self._workers[job.worker]
        try:
            wf = w.sortd.submit(job.keys)
        except QueueFull:
            self._overflow(job)
            return
        except RuntimeError:
            # closed/racing-dead worker: treat like a death drain for this job
            self._readmit_one(job, reason="worker-closed")
            return
        wf.add_done_callback(lambda f, job=job: self._job_done(job, f))

    def _overflow(self, job: _Job) -> None:
        """Worker queue full: spill to the least-loaded other live worker
        (overload stealing); all full ⇒ backpressure to the caller."""
        with self._lock:
            w = self._workers[job.worker]
            w.inflight.pop(job.id, None)
            candidates = [
                x for x in sorted(self._live)
                if x != job.worker and self._workers[x].sortd.backlog()
                < self.config.worker_config.max_queue
            ]
            if not candidates:
                self._rejected += 1
                self._inflight_total -= 1
                job.resolved = True
                self._not_full.notify_all()
                err: "Exception | None" = QueueFull(
                    "every live worker queue is at capacity"
                )
            else:
                err = None
                wid = min(candidates, key=lambda x: len(self._workers[x].inflight))
                if wid != job.worker:
                    self._steals += 1
                    self._workers[wid].steals_in += 1
                self._place(job, wid, new=False)
        if err is not None:
            try:
                job.future.set_exception(err)
            except InvalidStateError:
                pass
        else:
            self._dispatch(job)

    # ------------------------------------------------------------ completion
    def _resolve(self, job: _Job, *, result=None, error=None) -> None:
        """First resolution wins; later (duplicate) ones are no-ops."""
        with self._lock:
            if job.resolved:
                return
            job.resolved = True
            self._inflight_total -= 1
            w = self._workers[job.worker]
            w.inflight.pop(job.id, None)
            if error is None:
                self._completed += 1
                w.completed += 1
                lat = time.monotonic() - job.t_submit
                self._lat_s.append(lat)
                if len(self._lat_s) > self.config.latency_window:
                    del self._lat_s[: -self.config.latency_window]
            else:
                self._failed += 1
            self._not_full.notify_all()
        # outside the lock: client done-callbacks must not run under it
        try:
            if error is None:
                job.future.set_result(result)
            else:
                job.future.set_exception(error)
        except InvalidStateError:
            pass  # caller cancelled

    def _job_done(self, job: _Job, wf: Future) -> None:
        exc = wf.exception()
        if exc is not None:
            self._resolve(job, error=exc)
        else:
            self._resolve(job, result=wf.result())

    # -------------------------------------------------------------- failover
    def _on_worker_dead(self, wid: int, reason: str) -> None:
        """Health verdict: evict from routing, re-admit the backlog."""
        with self._lock:
            w = self._workers[wid]
            if w.state is not WorkerState.LIVE:
                return
            w.state = WorkerState.DEAD
            w.dead_reason = reason
            self._live.discard(wid)
            self._failovers += 1
            jobs = [j for j in w.inflight.values() if not j.resolved]
            w.inflight.clear()
            self._readmitted += len(jobs)
        for job in jobs:
            self._readmit_one(job, reason=reason)

    def _readmit_one(self, job: _Job, *, reason: str) -> None:
        with self._lock:
            if job.resolved:
                return
            try:
                wid = self._pick_worker(job.key)
            except FleetDown:
                wid = None
            if wid is not None:
                self._place(job, wid, new=False)
        if wid is None:
            self._resolve(
                job,
                error=FleetDown(
                    f"worker {job.worker} died ({reason}) with no live "
                    "worker left to re-admit to"
                ),
            )
        else:
            self._dispatch(job)

    # ---------------------------------------------------------------- faults
    def apply_fault_scenario(self, scenario: "FaultScenario | None") -> dict:
        """Map a simulator-side ``FaultScenario`` onto the live fleet
        (DESIGN.md §11) — the serving end of the ``net.faults`` vocabulary.

        Worker-hub node faults ``(w, 0)`` with ``w < workers`` become real
        worker deaths: the victim is crashed through the SAME
        ``Sortd.kill()`` path ``ChaosConfig`` uses, so the health monitor's
        drain-and-readmit failover serves its backlog (and chaos kills and
        simulated topology faults are literally one code path).  Every
        remaining link/node fault is the *residual* scenario, forwarded to
        each surviving worker's engine — subsequent flushes re-price their
        plans over the degraded topology, or fall back to the healthy host
        path when the residual gather is impossible.  ``None`` heals the
        engines (dead workers stay dead — failover is not undone).

        Returns (and records in ``report()``) a summary dict:
        ``{"scenario", "killed_workers", "residual_faults"}``.
        """
        killed: "list[int]" = []
        residual = scenario
        if scenario is not None:
            killed = sorted(
                g for g, l in scenario.failed_nodes
                if l == 0 and 0 <= g < self.config.workers
            )
            residual = self._residual_scenario(scenario, killed)
        for w in self._workers:
            if w.wid not in killed:
                w.sortd.set_fault_scenario(residual)
        for wid in killed:
            self.kill_worker(wid)
        summary = {
            "scenario": None if scenario is None else scenario.name,
            "killed_workers": killed,
            "residual_faults": 0 if residual is None else (
                len(residual.failed_links) + len(residual.failed_nodes)
            ),
        }
        with self._lock:
            self._fault_scenario = scenario
            self._fault_summary = None if scenario is None else summary
        return summary

    @staticmethod
    def _residual_scenario(
        scenario: FaultScenario, killed: "Sequence[int]"
    ) -> "FaultScenario | None":
        """The scenario minus the killed worker hubs and their links — what
        the *surviving* workers' engines must still serve under."""
        if not killed:
            return scenario if scenario.is_degraded else None
        hubs = {(w, 0) for w in killed}
        links = tuple(
            (a, b) for a, b in scenario.failed_links
            if tuple(a) not in hubs and tuple(b) not in hubs
        )
        nodes = tuple(n for n in scenario.failed_nodes if tuple(n) not in hubs)
        if not links and not nodes:
            return None
        return dataclasses.replace(
            scenario, failed_links=links, failed_nodes=nodes
        )

    # ---------------------------------------------------------------- chaos
    def _maybe_trigger_chaos(self) -> None:
        # under _lock, on the admitting client thread
        c = self.chaos
        if c is None:
            return
        if (
            c.kill_worker_after is not None
            and self._chaos_killed is None
            and self._admitted >= c.kill_worker_after
        ):
            victim = self._chaos_victim(c.kill_worker)
            if victim is not None:
                self._chaos_killed = victim
                # The kill goes through the FaultScenario mapping — chaos
                # and simulated topology faults are one code path (§11).
                self.apply_fault_scenario(c.scenario(victim))
        if (
            c.stall_worker_ms > 0.0
            and self._chaos_stalled is None
            and self._admitted >= c.stall_worker_after
        ):
            self._chaos_stalled = c.stall_worker
            self._workers[c.stall_worker].stall_ms_pending = c.stall_worker_ms

    def _chaos_victim(self, spec) -> "int | None":
        if spec == "busiest":
            live = sorted(self._live)
            if not live:
                return None
            return max(live, key=lambda wid: len(self._workers[wid].inflight))
        return int(spec) if int(spec) in self._live else None

    def kill_worker(self, wid: int) -> None:
        """Manual chaos: crash worker ``wid`` now (test surface)."""
        self._workers[wid].sortd.kill()

    def check_health_now(self) -> "list[tuple[int, str]]":
        """Synchronous health pass (deterministic test seam)."""
        return self._monitor.check_now()

    # -------------------------------------------------------------- metrics
    def live_workers(self) -> "list[int]":
        with self._lock:
            return sorted(self._live)

    def metrics(self) -> dict:
        """JSON-ready snapshot: fleet-wide + per-worker observability."""

        def pct(d, q):
            return float(np.percentile(np.asarray(d), q)) * 1e3 if d else 0.0

        now = time.monotonic()
        with self._lock:
            uptime = max(now - self._t_start, 1e-9)
            workers = {}
            pad_cells = valid_cells = 0
            busy_fracs = []
            for w in self._workers:
                sm = w.sortd.metrics()
                for b in sm["buckets"].values():
                    total = b["requests"]
                    # pad_waste is a ratio; recover cells via rows×bucket is
                    # lossy — aggregate the ratios weighted by requests.
                    pad_cells += b["pad_waste"] * total
                    valid_cells += (1.0 - b["pad_waste"]) * total
                busy = sm["busy_s"] / max(sm["uptime_s"], 1e-9)
                if w.state is WorkerState.LIVE:
                    busy_fracs.append(busy)
                workers[str(w.wid)] = {
                    "state": w.state.value,
                    "dead_reason": w.dead_reason,
                    "fault": getattr(w.engine.fault_scenario, "name", None),
                    "admitted": w.admitted,
                    "completed": w.completed,
                    "inflight": len(w.inflight),
                    "backlog": w.sortd.backlog(),
                    "steals_in": w.steals_in,
                    "busy_fraction": busy,
                    "sortd": sm,
                }
            return {
                "workers": workers,
                "fleet": {
                    "live_workers": sorted(self._live),
                    "admitted": self._admitted,
                    "completed": self._completed,
                    "failed": self._failed,
                    "rejected": self._rejected,
                    "inflight": self._inflight_total,
                    "steals": self._steals,
                    "failovers": self._failovers,
                    "readmitted": self._readmitted,
                    "fault_scenario": getattr(
                        self._fault_scenario, "name", None
                    ),
                    "latency_ms": {
                        "p50": pct(self._lat_s, 50),
                        "p99": pct(self._lat_s, 99),
                    },
                    "saturation": (
                        sum(busy_fracs) / len(busy_fracs) if busy_fracs else 0.0
                    ),
                    "pad_waste": (
                        pad_cells / (pad_cells + valid_cells)
                        if pad_cells + valid_cells
                        else 0.0
                    ),
                    "uptime_s": uptime,
                },
            }

    def report(self) -> dict:
        """The JSON artifact: metrics + config + chaos vocabulary, in the
        ``net.report`` mold (plain dict, ``write_json`` to persist)."""
        m = self.metrics()
        chaos: "dict | None" = None
        if self.chaos is not None:
            chaos = {
                "name": self.chaos.name,
                "kill_worker_after": self.chaos.kill_worker_after,
                "stall_worker_ms": self.chaos.stall_worker_ms,
                "killed_worker": self._chaos_killed,
                "stalled_worker": self._chaos_stalled,
            }
            if self._chaos_killed is not None:
                # shared vocabulary with the simulator's degraded schedules
                chaos["fault_scenario"] = self.chaos.scenario(
                    self._chaos_killed
                ).name
        return {
            "subsystem": "repro.serve.fleet",
            "config": {
                "workers": self.config.workers,
                "steal_watermark": self.config.steal_watermark,
                "steal_margin": self.config.steal_margin,
                "max_inflight": self.config.max_inflight,
                "heartbeat_interval_s": self.config.heartbeat_interval_s,
                "heartbeat_timeout_s": self.config.heartbeat_timeout_s,
                "idle_flush_s": self.config.worker_config.idle_flush_s,
            },
            "chaos": chaos,
            "faults": self._fault_summary,
            **m,
        }


def write_json(report: dict, path) -> None:
    """Persist a fleet report (CI artifact), ``net.report`` style."""
    import json
    import pathlib

    pathlib.Path(path).write_text(json.dumps(report, indent=1) + "\n")
