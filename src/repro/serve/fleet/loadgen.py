"""Shared load-generation for the fleet: request mixes and drive loops
(DESIGN.md §10).

One implementation used by three consumers — ``benchmarks/bench_fleet.py``
(the figure-quality runs), ``repro.perf.suites``' gated fleet cases (the
CI perf slice), and ``tests/test_fleet.py`` (chaos correctness) — so the
"same workload mix" clause of the fleet acceptance criteria is literal:
every comparison draws from :func:`request_mix` with the same seed.

The drive loops only require a ``submit(arr) -> Future`` callable, so a
single :class:`~repro.serve.sortd.Sortd` and a
:class:`~repro.serve.fleet.SortdFleet` are driven through the identical
code path (closed-loop: N synchronous clients submit → wait → repeat —
throughput is the output; open-loop: fixed arrival schedule — latency is
the output).
"""

from __future__ import annotations

import threading
import time

import numpy as np

__all__ = ["request_mix", "drive_closed_loop", "drive_open_loop"]


def request_mix(
    n_requests: int,
    *,
    dtype: str = "int32",
    seed: int = 11,
    max_bucket: int = 1 << 12,
    oversize_frac: float = 0.02,
) -> "list[np.ndarray]":
    """Serving-shaped request stream: concentrated small buckets + a thin
    oversize tail.

    10% of requests land in the 64–512 bucket, ~58% in 512–2048, 30% in
    2048–4096, and ``oversize_frac`` beyond ``max_bucket`` (exercising the
    per-array direct path — the head-of-line blocking case a fleet
    isolates).  Deterministic per seed.
    """
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_requests):
        r = rng.random()
        if r < oversize_frac:
            lo, hi = max_bucket + 1, max_bucket * 2
        elif r < oversize_frac + 0.10:
            lo, hi = 64, 512
        elif r < oversize_frac + 0.68:
            lo, hi = 512, 2048
        else:
            lo, hi = 2048, 4096
        n = int(rng.integers(lo, hi))
        out.append(rng.integers(0, 1 << 30, n).astype(dtype))
    return out


def drive_closed_loop(
    submit,
    reqs: "list[np.ndarray]",
    *,
    clients: int = 8,
    timeout: float = 120.0,
) -> "tuple[float, list]":
    """``clients`` synchronous clients round-robin the request list.

    Returns ``(wall_s, outs)`` with ``outs[i]`` the sorted result of
    ``reqs[i]``; raises if any request failed or timed out — a lost answer
    is a harness failure, never a silent hole in the results.
    """
    outs: list = [None] * len(reqs)
    errors: list = []

    def client(cid: int) -> None:
        for i in range(cid, len(reqs), clients):
            try:
                outs[i] = submit(reqs[i]).result(timeout=timeout)
            except Exception as e:  # noqa: BLE001 — reported, not swallowed
                errors.append((i, repr(e)))

    threads = [
        threading.Thread(target=client, args=(c,), name=f"loadgen-{c}")
        for c in range(clients)
    ]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise RuntimeError(
            f"{len(errors)}/{len(reqs)} requests failed: {errors[:3]}"
        )
    return wall, outs


def drive_open_loop(
    submit,
    reqs: "list[np.ndarray]",
    *,
    rate: float = 300.0,
    timeout: float = 120.0,
) -> "tuple[float, list]":
    """Fixed arrival schedule at ``rate`` req/s regardless of completion
    (arrival is the input, latency is the output).  Same return/raise
    contract as :func:`drive_closed_loop`."""
    period = 1.0 / rate
    futs = []
    t0 = time.perf_counter()
    for i, x in enumerate(reqs):
        delay = (t0 + i * period) - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        futs.append(submit(x))
    outs = [f.result(timeout=timeout) for f in futs]
    wall = time.perf_counter() - t0
    return wall, outs
