"""Affinity routing with admission-side work stealing (DESIGN.md §10).

Pure decision logic — no threads, no queues — so every property the fleet
relies on is unit-testable without timing:

* **Affinity** (:func:`rendezvous_worker`): each ``(dtype, pow2 bucket)``
  key maps to one worker by rendezvous (highest-random-weight) hashing
  over the *live* worker set.  Same key ⇒ same worker ⇒ that worker's
  warm jit cache serves every flush of the key; and when a worker dies,
  only ITS keys move (the rendezvous minimal-disruption property — the
  other workers' caches stay hot), the fleet analog of the OTIS
  fault-tolerance claim that a failed element perturbs only its own
  routes.  Hashing is ``crc32`` over the printable key, never Python's
  salted ``hash``: placement must be stable across runs so tests and the
  perf gate see one routing, and across processes so a future multi-host
  fleet agrees on it.

* **Stealing** (:meth:`AffinityRouter.route`): affinity concentrates load
  by design, so it needs a safety valve.  When the affine worker's
  backlog reaches ``steal_watermark`` AND the least-loaded live worker's
  backlog times ``steal_margin`` is still below it, the request is routed
  there instead (`RouteDecision.stolen`) — the underloaded worker steals
  the job at admission.  The margin keeps a marginal imbalance from
  flapping traffic (and cold caches) back and forth; the watermark keeps
  stealing OFF entirely until affinity actually hurts.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Mapping, Sequence

__all__ = ["AffinityRouter", "RouteDecision", "rendezvous_worker"]

AffinityKey = "tuple[str, int]"


def rendezvous_worker(key, workers: "Sequence[int]") -> int:
    """Highest-random-weight choice of worker for ``key`` — deterministic,
    uniform-ish, and minimally disrupted by membership changes."""
    if not workers:
        raise ValueError("no live workers to route to")
    token = f"{key[0]}/{key[1]}"
    return max(
        workers,
        key=lambda w: (zlib.crc32(f"{token}#{w}".encode()), w),
    )


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """Where one request goes and why."""

    worker: int  # chosen worker id
    affine: int  # where affinity alone would have sent it
    stolen: bool  # True when the watermark tripped and the choice differs


class AffinityRouter:
    """Stateless-per-request router; the only state is a placement cache
    keyed on (affinity key, live-set) so the common path is one dict hit."""

    def __init__(self, *, steal_watermark: int = 8, steal_margin: int = 2):
        if steal_watermark < 1:
            raise ValueError("steal_watermark must be >= 1")
        if steal_margin < 1:
            raise ValueError("steal_margin must be >= 1")
        self.steal_watermark = steal_watermark
        self.steal_margin = steal_margin
        self._cache: dict = {}

    def route(
        self,
        key,
        live: "Sequence[int]",
        backlogs: "Mapping[int, int]",
    ) -> RouteDecision:
        """Pick a worker for ``key`` given per-worker backlogs.

        ``live`` must be ordered deterministically (the fleet passes a
        sorted tuple); ``backlogs`` is a snapshot — staleness only costs
        steal quality, never correctness.
        """
        live_t = tuple(live)
        cached = self._cache.get((key, live_t))
        if cached is None:
            cached = rendezvous_worker(key, live_t)
            if len(self._cache) > 4096:  # bounded: keys × live-sets is small
                self._cache.clear()
            self._cache[(key, live_t)] = cached
        affine = cached
        depth = backlogs.get(affine, 0)
        if depth >= self.steal_watermark and len(live_t) > 1:
            thief = min(live_t, key=lambda w: (backlogs.get(w, 0), w))
            if thief != affine and backlogs.get(thief, 0) * self.steal_margin <= depth:
                return RouteDecision(worker=thief, affine=affine, stolen=True)
        return RouteDecision(worker=affine, affine=affine, stolen=False)
