"""repro.serve.fleet — multi-worker sortd serving (DESIGN.md §10).

N :class:`~repro.serve.sortd.Sortd` workers behind one admission layer:
(dtype, bucket)-affinity routing with watermark work stealing, heartbeat
health checking with drain-and-readmit failover, deterministic chaos
injection, and fleet-wide observability.  Load generation lives in
:mod:`repro.serve.fleet.loadgen` (bench/test-facing, not exported here).
"""

from repro.serve.fleet.fleet import (
    ChaosConfig,
    FleetConfig,
    FleetDown,
    SortdFleet,
    write_json,
)
from repro.serve.fleet.health import HealthMonitor, WorkerState
from repro.serve.fleet.routing import AffinityRouter, RouteDecision, rendezvous_worker

__all__ = [
    "SortdFleet",
    "FleetConfig",
    "ChaosConfig",
    "FleetDown",
    "AffinityRouter",
    "RouteDecision",
    "rendezvous_worker",
    "HealthMonitor",
    "WorkerState",
    "write_json",
]
