"""Worker health checking: liveness probes + heartbeat staleness
(DESIGN.md §10).

Two failure modes, two detectors, one verdict:

* **Crash** — the worker *thread* is gone (chaos ``kill()``, an escaped
  exception).  Detected by the liveness probe (``Thread.is_alive``)
  within one check interval; there is nothing to wait out.
* **Stall** — the thread is alive but stuck (chaos stall injection, a
  wedged engine call).  Detected by heartbeat staleness: workers beat via
  their tick hooks (per loop iteration and per flush), so a beat older
  than ``timeout_s`` means no scheduling progress.  ``timeout_s`` must
  exceed the worst single uninterruptible unit of work (one oversize
  direct sort) or a slow-but-healthy worker gets declared dead — that
  only costs duplicated work, never a wrong answer (the fleet's
  first-resolution-wins guard), but it is wasted capacity.

The monitor never *acts* on a worker — it calls ``on_dead(worker_id,
reason)`` exactly once per worker and lets the fleet own the drain, so
the policy (re-admission, routing eviction) stays in one place and the
monitor stays reusable.
"""

from __future__ import annotations

import dataclasses
import enum
import threading
import time
from typing import Callable

__all__ = ["HealthMonitor", "WorkerState"]


class WorkerState(enum.Enum):
    LIVE = "live"
    DEAD = "dead"


@dataclasses.dataclass
class _Probe:
    alive: "Callable[[], bool]"
    last_beat: "Callable[[], float]"
    dead: bool = False


class HealthMonitor:
    """Periodic prober; ``on_dead`` fires once per failed worker."""

    def __init__(
        self,
        *,
        interval_s: float = 0.05,
        timeout_s: float = 1.0,
        on_dead: "Callable[[int, str], None]",
    ):
        self.interval_s = interval_s
        self.timeout_s = timeout_s
        self._on_dead = on_dead
        self._probes: "dict[int, _Probe]" = {}
        self._stop = threading.Event()
        self._thread: "threading.Thread | None" = None

    def register(
        self,
        worker_id: int,
        *,
        alive: "Callable[[], bool]",
        last_beat: "Callable[[], float]",
    ) -> None:
        self._probes[worker_id] = _Probe(alive=alive, last_beat=last_beat)

    def start(self) -> "HealthMonitor":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._run, name="fleet-health", daemon=True
            )
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def check_now(self) -> "list[tuple[int, str]]":
        """One synchronous probe pass (the deterministic test seam).

        Returns the ``(worker_id, reason)`` verdicts it issued.
        """
        now = time.monotonic()
        verdicts = []
        for wid, probe in list(self._probes.items()):
            if probe.dead:
                continue
            if not probe.alive():
                reason = "crashed"
            elif now - probe.last_beat() > self.timeout_s:
                reason = "heartbeat-timeout"
            else:
                continue
            probe.dead = True
            verdicts.append((wid, reason))
            self._on_dead(wid, reason)
        return verdicts

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.check_now()
