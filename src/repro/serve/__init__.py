from repro.serve.engine import ServeEngine, Request
from repro.serve.sortd import Sortd, SortdConfig, QueueFull

__all__ = ["ServeEngine", "Request", "Sortd", "SortdConfig", "QueueFull"]
