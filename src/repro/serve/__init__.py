from repro.serve.engine import ServeEngine, Request
from repro.serve.sortd import Sortd, SortdConfig, QueueFull, WorkerKilled, affinity_key
from repro.serve.fleet import SortdFleet, FleetConfig, ChaosConfig, FleetDown

__all__ = [
    "ServeEngine",
    "Request",
    "Sortd",
    "SortdConfig",
    "QueueFull",
    "WorkerKilled",
    "affinity_key",
    "SortdFleet",
    "FleetConfig",
    "ChaosConfig",
    "FleetDown",
]
