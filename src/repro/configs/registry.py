"""Architecture registry: ``--arch <id>`` → config, model API, input specs.

``input_specs(cfg, shape)`` returns ``jax.ShapeDtypeStruct`` stand-ins for
every model input of that (arch × shape) cell — weak-type-correct,
shardable, zero allocation — exactly what ``jit(...).lower()`` wants for
the multi-pod dry-run.  Modality frontends are stubs per the assignment:
whisper gets frame embeddings, qwen2-vl gets patch embeddings + M-RoPE
position ids.

``cell_supported(arch, shape)`` encodes the assignment's skip rules:
* ``long_500k`` only for sub-quadratic attention (mamba2, zamba2, mixtral
  SWA, gemma3 local:global) — pure full-attention archs skip it;
* whisper decodes against its (stubbed) encoder context.
"""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

ARCHS: dict[str, str] = {
    "whisper-tiny": "repro.configs.whisper_tiny",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "minitron-4b": "repro.configs.minitron_4b",
    "qwen1.5-32b": "repro.configs.qwen1_5_32b",
    "qwen1.5-110b": "repro.configs.qwen1_5_110b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
}

# archs with sub-quadratic (or windowed/local) attention → run long_500k
LONG_CONTEXT_OK = {"mamba2-370m", "zamba2-2.7b", "mixtral-8x22b", "gemma3-4b"}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(ARCHS[arch])
    return mod.smoke_config() if smoke else mod.config()


def get_model_api(cfg: ModelConfig):
    """→ module with init/forward/(init_cache/prefill/decode_step)/param_specs."""
    if cfg.family == "encdec":
        from repro.models import encdec

        return encdec
    from repro.models import lm

    return lm


def cell_supported(arch: str, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False, "pure full-attention arch: long_500k skipped per assignment"
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in SHAPES]


def supported_cells() -> list[tuple[str, str]]:
    return [(a, s) for a, s in all_cells() if cell_supported(a, s)[0]]


# --------------------------------------------------------------- input specs
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for the *batch* argument of train/prefill/decode."""
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind == "train":
        specs = {"tokens": _sds((B, S), tok), "labels": _sds((B, S), tok)}
    elif shape.kind == "prefill":
        specs = {"tokens": _sds((B, S), tok)}
    else:  # decode: one new token against a seq_len-deep cache
        specs = {"tokens": _sds((B, 1), tok)}
    if cfg.family == "encdec":
        specs["enc_frames"] = _sds((B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
        specs.pop("labels", None)
        if shape.kind == "train":
            specs["labels"] = _sds((B, S), tok)
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["vision_embeds"] = _sds((B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        specs["positions_thw"] = _sds((3, B, S), tok)
    return specs


def shape_for(name: str) -> ShapeConfig:
    return SHAPES[name]
