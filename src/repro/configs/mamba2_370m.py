"""mamba2-370m [ssm; arXiv:2405.21060]: 48L, d=1024, attention-free,
ssm_state=128, vocab=50280.  SSD (state-space duality) blocks; decode is a
constant-memory state update — long_500k is the showcase shape."""

from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=1,  # unused (attention-free)
        num_kv_heads=1,
        d_ff=0,
        vocab_size=50280,
        tie_embeddings=True,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk_size=256),
        max_seq_len=524288 + 8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, vocab_size=512, max_seq_len=128,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk_size=32),
    )
