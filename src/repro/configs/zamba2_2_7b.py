"""zamba2-2.7b [hybrid; arXiv:2411.15242]: 54 Mamba2 blocks, d=2560,
ssm_state=64, plus ONE shared attention+MLP block (32H, d_ff=10240)
applied every 6 SSM blocks with the concat-embedding input (2d → d proj).
vocab=32000.  long_500k: SSM state is O(1); the shared attention block's
KV cache (9 applications × 500k) is seq-sharded — DESIGN.md §5."""

from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        hybrid_period=6,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab_size=32000,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk_size=256),
        max_seq_len=524288 + 8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=4, hybrid_period=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        max_seq_len=128, attn_chunk=32,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk_size=32),
    )
