"""mixtral-8x22b [moe; arXiv:2401.04088]: 56L, d=6144, 48H (GQA kv=8),
d_ff=16384, vocab=32768, 8 experts top-2, sliding-window attention.

The MoE layer uses the framework's **sort-based dispatch** — the paper's
Array Division Procedure applied to expert ids (DESIGN.md §3)."""

from repro.configs.base import MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        num_layers=56,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=32768,
        rope_theta=1e6,
        window_pattern=(4096,),  # SWA on every layer
        moe=MoEConfig(
            num_experts=8,
            num_experts_per_tok=2,
            expert_d_ff=16384,
            # production default: shard_map dispatch (tokens stay local,
            # one intra-pod psum) — §Perf Cell 3.  Revert: --levers paperbase
            dispatch="shard_map",
        ),
        # SWA everywhere → ring-buffer decode cache (§Perf Cell 1)
        decode_window_cache=True,
        max_seq_len=524288 + 8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=512, window_pattern=(32,), max_seq_len=128, attn_chunk=32,
        moe=MoEConfig(
            num_experts=4, num_experts_per_tok=2, expert_d_ff=64,
            dispatch="sorted", capacity_factor=4.0,
        ),
    )
