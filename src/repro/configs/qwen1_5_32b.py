"""qwen1.5-32b [dense; hf:Qwen/Qwen1.5-*]: 64L, d=5120, 40H (MHA kv=40),
d_ff=27392, vocab=152064, QKV bias."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=40,
        head_dim=128,
        d_ff=27392,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1e6,
        max_seq_len=32768 + 8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, max_seq_len=128, attn_chunk=32,
    )
