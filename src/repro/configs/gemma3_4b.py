"""gemma3-4b [dense; hf:google/gemma-3-4b-pt]: 34L, d=2560, 8H (GQA kv=4),
head_dim=256, d_ff=10240, vocab=262144.  5 local (window 1024) : 1 global
layer pattern; local layers rope theta 10k, global 1M; QK-norm; embeddings
scaled by sqrt(d).  long_500k note: only the ~6 global layers hold a full
500k KV (seq-sharded); local layers cache one window — see DESIGN.md §5."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        family="dense",
        num_layers=34,
        d_model=2560,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262144,
        qk_norm=True,
        embed_scale=True,
        window_pattern=(1024, 1024, 1024, 1024, 1024, 0),
        rope_theta=10000.0,
        rope_theta_global=1e6,
        tie_embeddings=True,
        max_seq_len=524288 + 8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, window_pattern=(32, 32, 32, 0),
        max_seq_len=128, attn_chunk=32,
    )
