"""qwen1.5-110b [dense; hf:Qwen/Qwen1.5-110B]: 80L, d=8192, 64H (GQA kv=8),
d_ff=49152, vocab=152064, QKV bias.  The heaviest assigned config —
the FSDP×TP memory stress test of the dry-run."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b",
        family="dense",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=49152,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1e6,
        max_seq_len=32768 + 8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, max_seq_len=128, attn_chunk=32,
    )
