"""whisper-tiny [audio; arXiv:2212.04356]: 4L enc + 4L dec, d=384, 6H,
d_ff=1536, vocab=51865.  Conv frontend is a STUB — ``input_specs`` provides
precomputed (B, 1500, 384) frame embeddings per the assignment."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="encdec",
        num_layers=4,
        encoder_layers=4,
        encoder_seq_len=1500,
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        norm="layernorm",
        act="gelu",
        use_rope=False,
        tie_embeddings=True,
        max_seq_len=32768 + 8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, encoder_layers=2, encoder_seq_len=16, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512, max_seq_len=128,
        attn_chunk=32,
    )
