"""minitron-4b [dense; arXiv:2407.14679]: pruned nemotron — 32L, d=3072,
24H (GQA kv=8), d_ff=9216, vocab=256000.  Nemotron uses a 2-matrix
(squared-ReLU) MLP; we use the gelu 2-matrix MLP (same shape/FLOPs)."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9216,
        vocab_size=256000,
        act="gelu",
        rope_theta=10000.0,
        max_seq_len=32768 + 8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, max_seq_len=128, attn_chunk=32,
    )
