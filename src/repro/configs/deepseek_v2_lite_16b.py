"""deepseek-v2-lite-16b [moe; arXiv:2405.04434]: 27L, d=2048, 16H,
MLA kv_lora=512 (rope 64 / nope 128 / v 128), 64 routed experts top-6 +
2 shared, expert d_ff=1408, vocab=102400.

NOTE: the assignment line reads "2 shared+160 routed top-6" while also
stating "MoE 64e top-6"; DeepSeek-V2-Lite has 64 routed experts — we follow
the 64e reading (and the paper).  MLA's latent KV cache (576 dims/token)
is exercised by the decode shapes; ``mla.absorb`` is the beyond-paper
decode optimisation toggle."""

from repro.configs.base import MLAConfig, MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        num_layers=27,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,
        vocab_size=102400,
        rope_theta=10000.0,
        mla=MLAConfig(
            kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=64,
            num_experts_per_tok=6,
            num_shared_experts=2,
            expert_d_ff=1408,
            shared_d_ff=1408,
            dispatch="shard_map",  # production default — §Perf bonus cell
            expert_parallel=True,  # 64 experts divide the 16-way TP axis
        ),
        max_seq_len=32768 + 8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=64,
        vocab_size=512, max_seq_len=128, attn_chunk=32,
        mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
        moe=MoEConfig(num_experts=8, num_experts_per_tok=2,
                      num_shared_experts=2, expert_d_ff=32, shared_d_ff=32,
                      dispatch="sorted", capacity_factor=4.0),
    )
