"""qwen2-vl-7b [vlm; arXiv:2409.12191]: 28L, d=3584, 28H (GQA kv=4),
d_ff=18944, vocab=152064, M-RoPE (sections 16/24/24 over head_dim 128),
dynamic resolution.  The vision tower is a STUB per the assignment:
``input_specs`` supplies precomputed patch embeddings (B, 1024, d) and the
(3, B, S) temporal/height/width position ids that M-RoPE consumes."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b",
        family="vlm",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1e6,
        mrope_sections=(16, 24, 24),
        vision_tokens=1024,
        max_seq_len=32768 + 8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, mrope_sections=(4, 2, 2), vision_tokens=8,
        max_seq_len=128, attn_chunk=32,
    )
