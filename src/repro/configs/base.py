"""Config system: one dataclass family covers all 10 assigned architectures.

``ModelConfig`` is intentionally a single wide dataclass (MaxText-style)
rather than per-family classes: every field has a safe default, each arch
file sets only what it needs, and the registry/CLI can override any field
with ``key=value`` pairs.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0  # 0 → dense MLP
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    expert_d_ff: int = 0  # per-expert hidden (deepseek ≠ dense d_ff)
    shared_d_ff: int = 0
    router_aux_loss: float = 0.01
    # 'sorted' (paper technique) | 'argsort' (same ranks via one stable
    # argsort — bit-identical, DESIGN.md §12) | 'dense'
    dispatch: str = "sorted"
    capacity_factor: float = 1.25
    expert_parallel: bool = False  # experts divide the TP axis (deepseek 64e)
    # §Perf lever: shard the (E, C, d) dispatch buffer's token dim over the
    # batch axes (and E over TP when expert_parallel) — without it the
    # grouped expert matmul loses the data-parallel sharding entirely.
    dispatch_sharded: bool = False


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 0  # 0 → standard GQA attention
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    absorb: bool = False  # decode-time W_uk absorption (beyond-paper opt)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 0  # 0 → no SSM layers
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int = 4
    d_model: int = 512
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 0  # 0 → d_model // num_heads
    d_ff: int = 2048
    vocab_size: int = 32000
    max_seq_len: int = 131072

    # attention flavour
    qkv_bias: bool = False  # qwen1.5
    qk_norm: bool = False  # gemma3
    embed_scale: bool = False  # gemma3: embeddings × sqrt(d_model)
    use_rope: bool = True  # whisper: absolute sinusoidal instead
    rope_theta: float = 10000.0
    rope_theta_global: float = 0.0  # gemma3: different theta for global layers
    window_pattern: tuple[int, ...] = ()  # per-layer window; 0 = global; cycled
    mrope_sections: tuple[int, ...] = ()  # qwen2-vl M-RoPE (t,h,w) head_dim split
    attn_logit_softcap: float = 0.0

    # norm / activation
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu (SwiGLU) | gelu
    tie_embeddings: bool = False

    moe: MoEConfig = MoEConfig()
    mla: MLAConfig = MLAConfig()
    ssm: SSMConfig = SSMConfig()

    # hybrid (zamba2): shared transformer block every k SSM blocks
    hybrid_period: int = 0  # 0 → not hybrid

    # enc-dec (whisper): encoder stack + cross attention
    encoder_layers: int = 0
    encoder_seq_len: int = 1500  # whisper audio frames (stub frontend)

    # vlm (qwen2-vl): stub patch embeddings prepended
    vision_tokens: int = 0

    # numerics / execution
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: bool = True
    scan_layers: bool = True
    attn_chunk: int = 1024  # KV-chunked (online-softmax) attention block
    # ---- perf levers (§Perf hillclimbs; False = paper-faithful baseline)
    attn_matmul_bf16: bool = False  # QKᵀ and P·V on the MXU in bf16, f32 accum
    prefill_inscan_cache: bool = False  # write KV cache inside the layer scan
    # ring-buffer KV cache sized to the attention window (valid only when
    # EVERY layer is windowed, e.g. mixtral SWA): long_500k decode cache
    # shrinks from O(seq) to O(window)
    decode_window_cache: bool = False

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.moe.num_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.ssm.d_state > 0 and self.hybrid_period == 0

    @property
    def is_hybrid(self) -> bool:
        return self.hybrid_period > 0

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim

    def layer_window(self, layer: int) -> int:
        """Per-layer attention window (0 = global) from the cycled pattern."""
        if not self.window_pattern:
            return 0
        return self.window_pattern[layer % len(self.window_pattern)]

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline bookkeeping)."""
        d, L, hd = self.d_model, self.num_layers, self.resolved_head_dim
        nH, nKV = self.num_heads, self.num_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "encdec"):
            if self.mla.kv_lora_rank:
                r, dr = self.mla.kv_lora_rank, self.mla.qk_rope_head_dim
                dn, dv = self.mla.qk_nope_head_dim, self.mla.v_head_dim
                per_layer += d * nH * (dn + dr)  # W_q
                per_layer += d * (r + dr)  # W_dkv + W_kr
                per_layer += r * nH * (dn + dv)  # W_uk + W_uv
                per_layer += nH * dv * d  # W_o
            else:
                per_layer += d * nH * hd + 2 * d * nKV * hd + nH * hd * d
            if self.is_moe:
                e = self.moe
                per_layer += d * e.num_experts  # router
                per_layer += 3 * d * e.expert_d_ff * e.num_experts
                per_layer += 3 * d * e.shared_d_ff * e.num_shared_experts
            else:
                mult = 3 if self.act == "silu" else 2
                per_layer += mult * d * self.d_ff
        if self.family == "ssm" or self.is_hybrid:
            s = self.ssm
            din = self.d_inner
            nh = self.ssm_heads
            per_layer_ssm = d * (2 * din + 2 * s.n_groups * s.d_state + nh)
            per_layer_ssm += din * d  # out_proj
            per_layer_ssm += s.d_conv * (din + 2 * s.n_groups * s.d_state)
            if self.family == "ssm":
                per_layer = per_layer_ssm
            else:
                # hybrid: L ssm blocks + ONE shared attention+mlp block
                shared = (
                    2 * d * nH * hd + 2 * d * nKV * hd + nH * hd * d + 3 * d * self.d_ff
                )
                return emb + L * per_layer_ssm + shared
        total = emb + L * per_layer
        if self.family == "encdec":
            enc_layer = d * nH * hd * 2 + 2 * d * nKV * hd + 2 * d * self.d_ff
            cross = d * nH * hd + 2 * d * nKV * hd + nH * hd * d
            total += self.encoder_layers * enc_layer + L * cross
        return total

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (seq_len, global_batch) input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution-level knobs shared by train/serve/dryrun."""

    model: ModelConfig = ModelConfig()
    shape: ShapeConfig = SHAPES["train_4k"]
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    grad_accum: int = 1  # microbatches per step (activation-memory control)
    grad_accum_unroll: bool = False  # python-loop microbatches (cost calib)
    master_weights: bool = False  # bf16 params + f32 master in opt state
    seed: int = 0
    # distribution
    fsdp_axis: str = "data"
    tensor_axis: str = "model"
    batch_axes: tuple[str, ...] = ("pod", "data")
    # fault tolerance
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    # optimizer comms
    grad_compression: str = "none"  # none | int8
