"""AdamW, pure JAX, pytree-native.  Optimizer states shard like params
(ZeRO: m/v inherit the FSDP×TP PartitionSpecs)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(params, grads, state, lr, cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "count": count},
        {"grad_norm": gnorm, "clip_scale": scale},
    )


def opt_state_specs(param_specs):
    """m/v shard exactly like their parameters; count is replicated."""
    from jax.sharding import PartitionSpec as P

    return {
        "m": param_specs,
        "v": param_specs,
        "count": P(),
    }
