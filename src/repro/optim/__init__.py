from repro.optim.adamw import adamw_init, adamw_update, AdamWConfig
from repro.optim.schedules import cosine_warmup
from repro.optim.compression import quantize_int8, dequantize_int8, compress_grads

__all__ = [
    "adamw_init",
    "adamw_update",
    "AdamWConfig",
    "cosine_warmup",
    "quantize_int8",
    "dequantize_int8",
    "compress_grads",
]
