"""Gradient compression for the data-parallel reduction (distributed-opt
trick): per-tensor int8 quantisation with **error feedback**.

At fleet scale the DP gradient all-reduce dominates the slow (inter-pod /
"optical") tier — exactly the link class the paper's schedule economises.
int8 + EF cuts those bytes 4× (bf16→int8×2 passes? no: one pass, scale in
f32) with no measurable loss degradation at these batch sizes (validated
in tests against fp32 training curves on the 100M example).

``compress_grads`` is the numerics model (quantise→dequantise with an EF
residual carried in the optimizer state); the shard_map int8-psum variant
for real bandwidth savings is in ``repro.runtime.collectives`` and used by
the hierarchical trainer configuration.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_grads(grads, error_fb):
    """Quantise each gradient leaf with error feedback.

    Returns (decompressed_grads, new_error_fb).  error_fb is a pytree like
    grads (f32) carrying the quantisation residual to the next step.
    """

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), gf - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_fb)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])


def init_error_fb(grads_or_params):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_or_params)
